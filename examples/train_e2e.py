"""End-to-end training driver: ~100M-parameter LM, synthetic data pipeline,
AdamW + WSD/cosine schedule, microbatched gradient accumulation, async
checkpointing with atomic publish, and preemption/restart recovery.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --preempt-at 40
    PYTHONPATH=src python examples/train_e2e.py --steps 300   # resumes at 40

The model is an olmo-family LM scaled to ~100M params (CPU-trainable); any
``--arch`` from the registry works (reduced configs for smoke, full configs
on real hardware). Fault tolerance is exercised for real: ``--preempt-at``
kills the process mid-run after a checkpoint; re-running resumes from the
latest published step with bit-identical data order (stateless data
iterator keyed on (seed, step)).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataIterator
from repro.train import trainer
from repro.train.optimizer import OptConfig


def model_100m() -> ModelConfig:
    """olmo-style dense LM, ~100M params (8L x 768, vocab 32k)."""
    return dataclasses.replace(
        registry.get("olmo-1b"), name="olmo-100m", num_layers=8,
        d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=32_000)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="100m",
                    help="'100m' or a registry id (reduced config)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/train_e2e_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption: exit after this step")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = model_100m() if args.arch == "100m" \
        else registry.get(args.arch).reduced()
    n_params_est = cfg.param_count()
    print(f"arch={cfg.name} ~{n_params_est/1e6:.1f}M params "
          f"(schedule={cfg.lr_schedule})")

    run = trainer.RunConfig(
        microbatches=args.microbatches, remat="none",
        opt=OptConfig(lr=args.lr, warmup_steps=20, schedule=cfg.lr_schedule,
                      total_steps=args.steps))
    state = trainer.init_state(cfg, run, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"actual params: {n_params/1e6:.1f}M")

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=3)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        _, state = ckpt.restore_latest(state)
        start_step = latest
        print(f"[restart] resumed from checkpoint step {start_step}")

    step_fn = jax.jit(trainer.make_train_step(cfg, run), donate_argnums=0)
    data = DataIterator(cfg, batch=args.batch, seq=args.seq,
                        start_step=start_step)

    t_last = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            dt = time.time() - t_last
            t_last = time.time()
            tok_s = args.batch * args.seq * args.log_every / max(dt, 1e-9)
            print(f"step {step+1:4d}  loss={loss:.4f}  "
                  f"lr={float(metrics.get('lr', 0)):.2e}  "
                  f"{tok_s/1e3:.1f}k tok/s", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)          # async, atomic
        if args.preempt_at is not None and step + 1 >= args.preempt_at:
            ckpt.wait()
            print(f"[preempt] simulated preemption at step {step+1} — "
                  f"re-run to resume")
            sys.exit(17)

    ckpt.wait()
    ckpt.save(args.steps, state, blocking=True)
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
