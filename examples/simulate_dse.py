"""Design-space exploration with the paper's models — three studies:

  A. Long-context DRAM-traffic regimes on H800 (paper §6.2 / Fig. 9):
     where the ideal-cache assumption breaks, and how far GenZ-style
     models underestimate.
  B. Sim-guided Pallas flash-attention block-size selection on TPU v5e
     (the paper's profiling-driven tile choice, §2.2, with SimFA-TPU as
     the profiler) for assigned-architecture attention shapes.
  C. Future-hardware what-if (§3.6): sweep effective L2 capacity and SM
     count; watch the bottleneck migrate and the wave factor collapse.

    PYTHONPATH=src python examples/simulate_dse.py
"""
from __future__ import annotations

from repro.configs import registry
from repro.configs.llama3 import AttnWorkload, workload
from repro.core import analytical
from repro.core.genz_baseline import genz_dram_traffic
from repro.core.machine import H800, TPU_V5E, h800_variant
from repro.core.tpu.autotune import autotune_flash


def study_a():
    print("=" * 72)
    print("A. DRAM traffic regimes, Llama-3 405B on H800 (GB per kernel)")
    print(f"{'seq':>8} {'regime':>10} {'waves':>6} {'SimFA':>9} "
          f"{'GenZ':>9} {'GenZ err':>9}")
    for s in (8192, 16384, 32768, 49152, 65536, 131072):
        w = workload("405B", s, batch=1)
        rep = analytical.analyze(w, H800)
        genz = genz_dram_traffic(w)
        err = (genz - rep.dram_bytes) / rep.dram_bytes
        print(f"{s:>8} {('ideal' if rep.ideal_regime else 'real'):>10} "
              f"{rep.waves_per_group:>6} {rep.dram_bytes/1e9:>9.2f} "
              f"{genz/1e9:>9.2f} {err:>+9.1%}")
    print("-> beyond the Eq.(4) boundary GenZ underestimates by the wave "
          "factor;\n   long-context DSE on ideal-cache models picks the "
          "wrong designs (paper §6.2.3)\n")


def study_b():
    print("=" * 72)
    print("B. SimFA-TPU-guided flash block sizes (TPU v5e)")
    cases = [
        ("qwen2.5-3b", "prefill_32k", 32768),
        ("command-r-plus-104b", "prefill_32k", 32768),
        ("dbrx-132b", "train_4k", 4096),
        ("olmo-1b", "train_4k", 4096),
    ]
    print(f"{'arch':>22} {'shape':>12} {'bq':>5} {'bk':>5} {'st':>3} "
          f"{'pred us':>9} {'bound':>6} {'vmem MB':>8}")
    for arch, shape, seq in cases:
        cfg = registry.get(arch)
        w = AttnWorkload(name=f"{arch}-{shape}", B=1, L=seq, S=seq,
                         H_kv=cfg.num_kv_heads, G=cfg.q_group_size,
                         D=cfg.head_dim, causal=True)
        plan = autotune_flash(w, TPU_V5E, causal=True)
        print(f"{arch:>22} {shape:>12} {plan.block_q:>5} {plan.block_k:>5} "
              f"{plan.stages:>3} {plan.predicted_us:>9.1f} "
              f"{plan.bottleneck:>6} {plan.vmem_bytes/1e6:>8.2f}")
    print("-> the framework picks kernel schedules by modeling the "
          "pipeline,\n   exactly how FA3 picks T_M/T_N by profiling "
          "(paper §2.2)\n")


def study_c():
    print("=" * 72)
    print("C. What-if hardware sweep, Llama-3 70B @ 64K (H800 baseline)")
    w = workload("70B", 65536, batch=1)
    print(f"{'variant':>28} {'regime':>8} {'waves':>6} {'DRAM GB':>9} "
          f"{'bottleneck':>11} {'latency ms':>11}")
    variants = [
        ("H800 (50MB L2, 132 SM)", {}),
        ("2x L2 (100MB)", {"l2_bytes": 100 * 1024 * 1024}),
        ("4x L2 (200MB)", {"l2_bytes": 200 * 1024 * 1024}),
        ("2x SMs (264)", {"num_sms": 264}),
        ("2x DRAM BW", {"dram_bw_gbps": 6700.0}),
    ]
    for name, kw in variants:
        cfg = h800_variant(**kw)
        rep = analytical.analyze(w, cfg)
        print(f"{name:>28} {('ideal' if rep.ideal_regime else 'real'):>8} "
              f"{rep.waves_per_group:>6} {rep.dram_bytes/1e9:>9.2f} "
              f"{rep.bottleneck:>11} {rep.latency*1e3:>11.2f}")
    print("-> more SMs / DRAM BW do not fix long-context attention; "
          "SRAM\n   (L2 capacity -> regime, T_M -> intensity) does "
          "(paper §3.6.2)\n")


if __name__ == "__main__":
    study_a()
    study_b()
    study_c()
