"""Quickstart: the three faces of the framework in ~a minute on CPU.

  1. train a tiny LM a few steps (model zoo + trainer substrate),
  2. decode from it with the serving engine (batched requests),
  3. predict an H800 FlashAttention-3 kernel's latency with the Sim-FA
     cycle simulator and SimFA-python analytical model (the paper's core).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.llama3 import workload
from repro.core import analytical
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3
from repro.data.synthetic import DataIterator
from repro.serve.engine import Request, ServeEngine
from repro.train import trainer
from repro.train.optimizer import OptConfig


def main():
    # ------------------------------------------------------ 1. train
    cfg = registry.get("olmo-1b").reduced()
    print(f"[1/3] training {cfg.name} ({cfg.num_layers}L d={cfg.d_model}) ...")
    run = trainer.RunConfig(microbatches=1, remat="none",
                            opt=OptConfig(lr=3e-3, warmup_steps=5))
    state = trainer.init_state(cfg, run, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_train_step(cfg, run), donate_argnums=0)
    data = DataIterator(cfg, batch=8, seq=32)
    losses = []
    for i, batch in zip(range(8), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        print(f"    step {i}: loss={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss should decrease"

    # ------------------------------------------------------ 2. serve
    print("[2/3] serving 6 batched requests ...")
    eng = ServeEngine(cfg, state.params, slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                    max_new=4) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        print(f"    req {r.rid}: {r.out}")
    assert all(len(r.out) == 4 for r in reqs)

    # ------------------------------------------------------ 3. simulate
    print("[3/3] Sim-FA: llama3-8B attention @ seq 1024 on H800 ...")
    w = workload("8B", 1024, batch=1)
    sim = simulate_fa3(w, H800, fidelity="auto")
    rep = analytical.analyze(w, H800)
    print(f"    cycle-sim latency : {sim.latency_us:9.1f} us "
          f"(fidelity={sim.fidelity}, tensor-core util {sim.tc_util:.0%})")
    print(f"    analytical latency: {rep.latency*1e6:9.1f} us "
          f"(bottleneck: {rep.bottleneck})")
    print(f"    L2 traffic        : sim {sim.l2_bytes/1e6:.1f} MB vs "
          f"Eq.(2) {rep.l2_bytes/1e6:.1f} MB")
    print(f"    DRAM traffic      : sim {sim.dram_bytes/1e6:.1f} MB vs "
          f"model {rep.dram_bytes/1e6:.1f} MB "
          f"({'ideal' if rep.ideal_regime else 'realistic'} regime, "
          f"{rep.waves_per_group} wave(s))")
    print("quickstart OK")


if __name__ == "__main__":
    main()
