"""End-to-end serving driver: batched requests through the continuous-
batching engine, with the Sim-FA performance model supplying the straggler
deadline (the paper's simulator as a production feature, DESIGN.md §4).

    PYTHONPATH=src python examples/serve_e2e.py --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.configs.llama3 import AttnWorkload
from repro.core.machine import TPU_V5E
from repro.core.tpu.analytical import analyze_tpu
from repro.models import api
from repro.serve.engine import Request, ServeEngine, StragglerPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch).reduced()
    print(f"serving {cfg.name}: {args.requests} requests, "
          f"{args.slots} slots, prompt={args.prompt_len}")
    params = api.init(cfg, jax.random.PRNGKey(0))

    # SimFA-TPU predicts the decode attention time for the target hardware;
    # the engine flags steps slower than factor x prediction as stragglers.
    w = AttnWorkload(name="deadline", B=args.slots, L=1,
                     S=args.max_seq, H_kv=cfg.num_kv_heads or 4,
                     G=cfg.q_group_size or 1, D=cfg.head_dim)
    pred = analyze_tpu(w, TPU_V5E)
    # CPU interpret-mode serving is orders slower than TPU: scale the
    # deadline to wall-clock by a measured calibration step instead
    policy = StragglerPolicy(expected_step_s=0.05, factor=20.0)
    print(f"  SimFA-TPU decode-attention prediction: "
          f"{pred.latency*1e6:.1f} us/step on {TPU_V5E.name} "
          f"(bottleneck: {pred.bottleneck})")

    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=args.max_seq,
                      straggler=policy)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    while eng.queue or any(eng.active):
        eng.step()
    dt = time.time() - t0

    toks = sum(len(r.out) for r in reqs)
    assert all(len(r.out) == args.max_new for r in reqs)
    print(f"  generated {toks} tokens in {eng.steps} engine steps, "
          f"{dt:.2f}s wall ({toks/dt:.1f} tok/s CPU-interpret)")
    print(f"  straggler watchdog: {eng.straggler.slow_steps} slow steps")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.out}")
    print("serve_e2e OK")


if __name__ == "__main__":
    main()
