"""Pipeline analysis CLI: stall attribution, critical path, what-if replay.

Simulate one FlashAttention-3 launch with event recording, then ask the
questions the flat gantt chart could not answer:

  where did each warpgroup's idle cycles go?      (stall buckets)
  what sequence of operations bounds the kernel?  (critical path)
  what if TMA bandwidth / WGMMA throughput / softmax cost changed?
                                                  (DAG replay, no resim)

    PYTHONPATH=src python examples/analyze_pipeline.py
    PYTHONPATH=src python examples/analyze_pipeline.py \
        --model 8B --seqlen 2048 --knob tma_bw=2 --knob wgmma=1.5
    PYTHONPATH=src python examples/analyze_pipeline.py \
        --sweep tma_bw=0.5,1,2,4 --json results/whatif.json
    PYTHONPATH=src python examples/analyze_pipeline.py \
        --report --trace-out results/fa3.trace.json   # open in ui.perfetto.dev
    PYTHONPATH=src python examples/analyze_pipeline.py \
        --kernel fa2 --verify                # pre-simulation lint, exit != 0
                                             # when the spec is illegal
"""
from __future__ import annotations

import argparse
import sys

from repro import obs
from repro.analysis import critical_path as cp
from repro.analysis import dag as dag_mod
from repro.analysis import report
from repro.analysis.sweep import SweepPoint, knob_grid, run_sweep
from repro.configs.llama3 import FAMILY, AttnWorkload, workload
from repro.core.kprog import registry as kernel_registry
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3


def _parse_knob(spec: str):
    name, _, val = spec.partition("=")
    if name not in ("tma_bw", "wgmma", "softmax"):
        raise argparse.ArgumentTypeError(f"unknown knob {name!r}")
    return name, [float(v) for v in val.split(",")]


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--model", default="8B", choices=("8B", "70B", "405B"))
    ap.add_argument("--kernel", default="fa3",
                    choices=kernel_registry.available(),
                    help="registered kernel program to analyze "
                         "(splitkv_decode forces a decode-shaped workload)")
    ap.add_argument("--seqlen", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--causal", action="store_true")
    ap.add_argument("--fidelity", default="auto",
                    choices=("auto", "full", "hierarchical"))
    ap.add_argument("--knob", action="append", default=[], type=_parse_knob,
                    metavar="NAME=K[,K...]",
                    help="what-if multiplier(s): tma_bw / wgmma / softmax; "
                         "repeatable, values form a cartesian grid")
    ap.add_argument("--sweep", action="append", default=[], type=_parse_knob,
                    help="alias of --knob (reads better for multi-point runs)")
    ap.add_argument("--top", type=int, default=8,
                    help="show the N widest-idle warpgroups (0 = all)")
    ap.add_argument("--json", default="", help="dump results to this path")
    ap.add_argument("--trace-out", default="",
                    help="export a Perfetto/Chrome trace_event JSON of the "
                         "run (PipeEvents + counter tracks) to this path; "
                         "open in ui.perfetto.dev")
    ap.add_argument("--report", action="store_true",
                    help="print the NCU-style section report (speed-of-"
                         "light %%, occupancy, stall buckets)")
    ap.add_argument("--counter-window", type=int, default=256,
                    help="PM-counter sampling window in cycles")
    ap.add_argument("--verify", action="store_true",
                    help="statically verify the kernel program for this "
                         "workload (deadlock freedom, ring/barrier/commit "
                         "protocol, hazards) and exit: 0 clean, 1 errors. "
                         "A pre-simulation lint — nothing is simulated.")
    args = ap.parse_args()

    if args.kernel == "splitkv_decode":
        # decode shape: one new token per sequence against a resident cache
        f = FAMILY[args.model]
        w = AttnWorkload(name=f"llama3-{args.model}-decode-s{args.seqlen}",
                         B=args.batch, L=1, S=args.seqlen,
                         H_kv=f["H_kv"], G=f["G"], D=f["D"])
    else:
        w = workload(args.model, args.seqlen, batch=args.batch,
                     causal=args.causal)

    if args.verify:
        from repro.core.kprog.verify import verify_spec
        spec = kernel_registry.get(args.kernel, verify=False)
        vrep = verify_spec(spec, cfg=H800, w=w)
        print(vrep.render())
        sys.exit(0 if vrep.ok else 1)

    print(f"simulating {w.name} ({args.kernel}) on {H800.name} "
          f"(fidelity={args.fidelity}) ...")
    want_counters = bool(args.trace_out) or args.report
    res = simulate_fa3(w, H800, fidelity=args.fidelity, record_events=True,
                       record_counters=want_counters,
                       counter_window=args.counter_window,
                       kernel=args.kernel)
    print(f"  {res.cycles:.0f} cycles = {res.latency_us:.1f} us "
          f"({res.fidelity}, {len(res.trace.events)} events)\n")

    if args.report:
        rep_ncu = obs.build_report(res, H800, workload=w,
                                   manifest=res.manifest)
        print(obs.render_report(rep_ncu))
        print()

    dag = dag_mod.build(res.trace.events, res.trace.dispatch_parent)

    rep = cp.attribute_stalls(dag)
    print(report.render_stall_report(rep, top=args.top))
    print()
    print("per-role totals (declared warpgroup roles):")
    for role, buckets in sorted(rep.by_role().items()):
        parts = ", ".join(f"{k}={v}" for k, v in sorted(buckets.items())
                          if v and k not in ("busy", "idle"))
        print(f"  {role:12s} busy={buckets['busy']} idle={buckets['idle']}"
              + (f"  ({parts})" if parts else ""))
    print()

    path = cp.critical_path(dag)
    summary = cp.path_summary(dag, path)
    print(report.render_critical_path(dag, path, summary))
    print()

    knob_axes = {"tma_bw": (1.0,), "wgmma": (1.0,), "softmax": (1.0,)}
    for name, vals in args.knob + args.sweep:
        knob_axes[name] = tuple(vals)
    grid = knob_grid(**knob_axes)
    if len(grid) > 1 or not grid[0].is_baseline():
        rows = run_sweep([SweepPoint(workload=w, machine=H800,
                                     fidelity=args.fidelity,
                                     kernel=args.kernel)],
                         grid, processes=1)
        print(report.render_whatif_table(rows))
    else:
        rows = []
        print("(no what-if knobs given; try --knob tma_bw=0.5,1,2)")

    if args.trace_out:
        obs.export_trace(args.trace_out, res.trace, res.counters,
                         res.manifest, name=f"{w.name} ({args.kernel})")
        print(f"\nwrote {args.trace_out} (open in ui.perfetto.dev)")

    if args.json:
        report.save_json(args.json, {
            "workload": w.name, "kernel": args.kernel, "cycles": res.cycles,
            "stalls": {"per_wg": rep.per_wg, "meta": rep.meta,
                       "totals": rep.totals()},
            "critical_path_summary": summary,
            "whatif": rows,
        }, manifest=res.manifest)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
