"""Scheduler equivalence: event-driven vs. waiter-indexed vs. broadcast.

The cycle engine's default run loop is discrete-event (PR 6): per-SM
issue-eligible ready queues, coalesced busy-timer wakes, and straight jumps
to the next interesting cycle.  The condition-indexed waiter scheduler
(PR 4) and the legacy broadcast scheduler (wake-everything-and-rescan)
survive behind ``Engine(scheduler="waiter")`` / ``Engine(scheduler=
"broadcast")`` (= ``broadcast_wake=True``) as fallbacks.  All three must be
*bit-exact*: identical ``Engine.stats()`` dicts and identical
:class:`EventTracer` event streams, across a grid of workload/machine
configs and all four registered kernel programs, including deadlock cases
(every mode flags ``deadlocked``, none hangs).

The GOLD values double as a regression anchor: ``cycles``, ``dram_bytes``,
``l2_req_bytes`` and ``tma_lines`` were captured from the pre-refactor
broadcast engine on this grid and must never drift.  The full-fidelity FA3
reference launch is separately pinned at 73614 cycles.
"""
import pytest

from repro.configs.llama3 import AttnWorkload
from repro.core import isa
from repro.core.engine import CTATrace, Engine
from repro.core.isa import Instr
from repro.core.kprog import registry
from repro.core.machine import H800, h800_variant
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas
from repro.analysis.events import EventTracer

SCHEDULERS = ("event", "waiter", "broadcast")

# name -> (machine, n_sms, workload kwargs)
CONFIGS = {
    "tiny": (H800, 2,
             dict(B=1, L=128, S=256, H_kv=1, G=1, D=64,
                  tiling=FA3Tiling(t_m=64, t_n=128, stages=2))),
    "small": (H800, 4, dict(B=1, L=256, S=512, H_kv=1, G=2, D=128)),
    "causal": (h800_variant(tma_max_inflight_lines=8, lrc_enabled=False), 2,
               dict(B=1, L=256, S=512, H_kv=1, G=1, D=128, causal=True)),
    "nox": (h800_variant(xor_hash=False, remote_copy=False), 3,
            dict(B=1, L=192, S=384, H_kv=1, G=1, D=64,
                 tiling=FA3Tiling(t_m=64, t_n=96, stages=3))),
}

# pre-refactor broadcast-engine reference values (see module docstring)
GOLD = {
    "tiny": {"cycles": 8666, "dram_bytes": 98304, "l2_req_bytes": 114688,
             "tma_lines": 1408, "tc_busy_cycles": 4096, "events": 328},
    "small": {"cycles": 26421, "dram_bytes": 524288, "l2_req_bytes": 962688,
              "tma_lines": 19968, "tc_busy_cycles": 67584, "events": 2592},
    "causal": {"cycles": 60209, "dram_bytes": 311296, "l2_req_bytes": 737280,
               "tma_lines": 5760, "tc_busy_cycles": 16896, "events": 672},
    "nox": {"cycles": 9805, "dram_bytes": 147456, "l2_req_bytes": 172032,
            "tma_lines": 2880, "tc_busy_cycles": 9216, "events": 852},
}

# the reference full-fidelity FA3 launch (BENCH_engine "full"): pinned
FULL_ANCHOR = {"cycles": 73614, "dram_bytes": 4194304,
               "l2_req_bytes": 31705728, "tma_lines": 565248}


def _events(tracer):
    return [(e.eid, e.kind, e.op, e.sm, e.cta, e.wg, e.tag, e.t0, e.t1,
             e.t_done, e.sid, e.gid, e.bid, e.dep_n, e.fixed, e.src)
            for e in tracer.events]


def _run(name, scheduler):
    cfg, n_sms, kw = CONFIGS[name]
    kw = dict(kw)
    tiling = kw.pop("tiling", FA3Tiling())
    causal = kw.pop("causal", False)
    ctas, tmaps = fa3_kernel_ctas(cfg, tiling=tiling, causal=causal, **kw)
    tracer = EventTracer()
    eng = Engine(cfg, n_sms=n_sms, mem_scale=n_sms / cfg.num_sms,
                 tracer=tracer, scheduler=scheduler)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return eng, st, _events(tracer)


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_schedulers_bit_exact(name):
    """All three schedulers: identical stats dicts and event streams."""
    eng_e, st_e, ev_e = _run(name, "event")
    assert eng_e.deadlocked is False
    for fallback in ("waiter", "broadcast"):
        eng_f, st_f, ev_f = _run(name, fallback)
        assert st_e == st_f, f"stats diverge: event vs {fallback}"
        assert ev_e == ev_f, f"event stream diverges: event vs {fallback}"
        assert eng_f.deadlocked is False


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_stats_match_pre_refactor_gold(name):
    _, st, ev = _run(name, "event")
    gold = GOLD[name]
    got = {k: st[k] for k in ("cycles", "dram_bytes", "l2_req_bytes",
                              "tma_lines", "tc_busy_cycles")}
    got["events"] = len(ev)
    assert got == gold


def test_broadcast_wake_flag_still_selects_broadcast():
    """Back-compat: ``broadcast_wake=True`` is the broadcast scheduler."""
    eng = Engine(H800, n_sms=1, mem_scale=1.0, broadcast_wake=True)
    assert eng.scheduler == "broadcast"
    assert eng.broadcast_wake is True
    with pytest.raises(ValueError):
        Engine(H800, n_sms=1, mem_scale=1.0, broadcast_wake=True,
               scheduler="event")
    with pytest.raises(ValueError):
        Engine(H800, n_sms=1, mem_scale=1.0, scheduler="nonsense")


def test_fa3_reference_anchor_73614():
    """The full-fidelity reference FA3 launch (all 132 SMs, 64 CTAs) under
    the default event scheduler: cycle count and traffic pinned forever."""
    w = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=FA3Tiling(), **w)
    eng = Engine(H800)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    assert eng.scheduler == "event"     # the default
    got = {k: st[k] for k in FULL_ANCHOR}
    assert got == FULL_ANCHOR


def _run_with_counters(name, scheduler):
    """Same launch as ``_run`` but with the PM-counter sink attached."""
    from repro.obs import CounterSink
    cfg, n_sms, kw = CONFIGS[name]
    kw = dict(kw)
    tiling = kw.pop("tiling", FA3Tiling())
    causal = kw.pop("causal", False)
    ctas, tmaps = fa3_kernel_ctas(cfg, tiling=tiling, causal=causal, **kw)
    tracer = EventTracer()
    snk = CounterSink(window=128)
    eng = Engine(cfg, n_sms=n_sms, mem_scale=n_sms / cfg.num_sms,
                 tracer=tracer, scheduler=scheduler, counters=snk)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return snk, st, _events(tracer)


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_counter_sink_is_bit_neutral(scheduler):
    """Attaching the observability sink must not perturb the simulation:
    stats dicts and event streams identical with counters on vs. off, for
    every scheduler."""
    _, st_off, ev_off = _run("small", scheduler)
    snk, st_on, ev_on = _run_with_counters("small", scheduler)
    assert st_on == st_off, f"counters perturb stats under {scheduler}"
    assert ev_on == ev_off, f"counters perturb events under {scheduler}"
    assert len(snk.cycles) > 1      # and the sink actually sampled


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_fa3_reference_anchor_73614_with_counters(scheduler):
    """The pinned full-fidelity anchor must hold with the counter sink AND
    the hazard sanitizer attached, under every scheduler — the acceptance
    bar for the observability layer and the sanitizer's bit-neutrality."""
    from repro.obs import CounterSink
    w = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=FA3Tiling(), **w)
    snk = CounterSink()
    eng = Engine(H800, counters=snk, scheduler=scheduler, sanitize=True)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    got = {k: st[k] for k in FULL_ANCHOR}
    assert got == FULL_ANCHOR
    assert snk.totals["dram_bytes"] == FULL_ANCHOR["dram_bytes"]
    assert snk.totals["tma_lines"] == FULL_ANCHOR["tma_lines"]
    assert eng.sanitizer.n_issues == 0      # pristine kernel, zero noise


# kernel-program grid: all four registered kernels, lowered through the
# registry, must also be scheduler-bit-exact (kernel -> machine, n_sms,
# workload, tiling)
KERNEL_CONFIGS = {
    "fa3": (H800, 4,
            AttnWorkload(name="p", B=1, L=256, S=512, H_kv=1, G=2, D=128),
            None),
    "fa3_cooperative": (h800_variant(num_sms=4), 4,
                        AttnWorkload(name="c", B=1, L=256, S=512, H_kv=1,
                                     G=2, D=128), None),
    "fa2": (H800, 3,
            AttnWorkload(name="f", B=1, L=192, S=384, H_kv=1, G=1, D=64),
            None),
    "splitkv_decode": (H800, 4,
                       AttnWorkload(name="d", B=2, L=1, S=2048, H_kv=2,
                                    G=4, D=128), None),
}


def _run_kernel(name, scheduler):
    cfg, n_sms, w, tiling = KERNEL_CONFIGS[name]
    ctas, tmaps = registry.get(name).build(cfg, w, tiling=tiling)
    tracer = EventTracer()
    eng = Engine(cfg, n_sms=n_sms, mem_scale=n_sms / cfg.num_sms,
                 tracer=tracer, scheduler=scheduler)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return eng, st, _events(tracer)


@pytest.mark.parametrize("name", sorted(KERNEL_CONFIGS))
def test_schedulers_bit_exact_on_kernel_specs(name):
    eng_e, st_e, ev_e = _run_kernel(name, "event")
    assert eng_e.deadlocked is False
    for fallback in ("waiter", "broadcast"):
        eng_f, st_f, ev_f = _run_kernel(name, fallback)
        assert st_e == st_f, f"stats diverge: event vs {fallback}"
        assert ev_e == ev_f, f"event stream diverges: event vs {fallback}"
        assert eng_f.deadlocked is False


def test_decode_traffic_crosschecks_analytical_hook():
    """Analytical-vs-simulated traffic for a decode workload: the split-KV
    spec's Eq.-2/6-style hooks must predict the engine's counters."""
    name = "splitkv_decode"
    cfg, _, w, _ = KERNEL_CONFIGS[name]
    spec = registry.get(name)
    _, st, _ = _run_kernel(name, "event")
    assert st["tma_lines"] * cfg.line_bytes == \
        pytest.approx(spec.l2_traffic(w), rel=0.05)
    assert st["dram_bytes"] == pytest.approx(
        spec.dram_real(w, 64, cfg.num_sms, cfg.occupancy_limit), rel=0.05)


def test_deadlock_flagged_identically():
    """An un-signaled mbarrier wait must deadlock-flag in every mode, and
    terminate immediately (no hang, no cycle burn)."""
    for scheduler in SCHEDULERS:
        eng = Engine(H800, n_sms=1, mem_scale=1.0, scheduler=scheduler)
        eng.launch([CTATrace(wgs=[[Instr(isa.MB_WAIT, sid=7)]],
                             n_consumers=1)])
        st = eng.run()
        assert eng.deadlocked, scheduler
        assert st["cycles"] == 0, scheduler


def test_deadlock_after_progress():
    """Deadlock reached mid-pipeline (producer waits on a stage no consumer
    releases): every mode agrees on the flag and on the cycle it is hit."""
    results = {}
    for scheduler in SCHEDULERS:
        prod = [Instr(isa.BUBBLES, cycles=100),
                Instr(isa.ACQUIRE_STAGE, sid=0),
                Instr(isa.ACQUIRE_STAGE, sid=0)]   # second acquire: no release
        eng = Engine(H800, n_sms=1, mem_scale=1.0, scheduler=scheduler)
        eng.launch([CTATrace(wgs=[prod], n_consumers=1)])
        st = eng.run()
        results[scheduler] = (eng.deadlocked, st["cycles"])
    assert len(set(results.values())) == 1, results
    assert results["event"][0] is True


# ---------------------------------------------------------------------------
# tile-granular memory fidelity (Engine(mem_fidelity="tile"))
# ---------------------------------------------------------------------------

# the reference full-fidelity launch under mem_fidelity="tile": pinned.
# dram_bytes / tma_lines are byte-identical to FULL_ANCHOR by construction
# (refcounted per-line residency); cycles and l2_req_bytes are approximated
# within the docs/fidelity.md bounds (-1.12% / -1.69% vs line-exact).
TILE_ANCHOR = {"cycles": 72792, "dram_bytes": 4194304,
               "l2_req_bytes": 31170560, "tma_lines": 565248}

TILE_CYCLE_ERR_MAX = 0.05


def _run_kernel_mem(name, mem_fidelity):
    """KERNEL_CONFIGS launch at full machine scale (tile mode's contract:
    simfa only selects it for full-machine launches)."""
    cfg, _, w, tiling = KERNEL_CONFIGS[name]
    ctas, tmaps = registry.get(name).build(cfg, w, tiling=tiling)
    eng = Engine(cfg, mem_fidelity=mem_fidelity)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    return eng, eng.run()


@pytest.mark.parametrize("name", sorted(KERNEL_CONFIGS))
def test_tile_fidelity_traffic_identical_cycles_bounded(name):
    """Every registered kernel: tile mode must reproduce dram_bytes,
    tma_lines and L2 misses byte-identically and keep cycle error within
    the documented bound."""
    _, line = _run_kernel_mem(name, "line")
    _, tile = _run_kernel_mem(name, "tile")
    for key in ("dram_bytes", "tma_lines"):
        assert line[key] == tile[key], f"{name}: {key} drifted"
    assert line["l2"]["misses"] == tile["l2"]["misses"], name
    err = abs(tile["cycles"] / line["cycles"] - 1.0)
    assert err <= TILE_CYCLE_ERR_MAX, (
        f"{name}: tile cycle error {err:.2%} "
        f"({tile['cycles']} vs {line['cycles']})")


def test_tile_fidelity_reference_anchor_72792():
    """The reference FA3 launch in tile mode: pinned forever, traffic
    byte-identical to the line-exact FULL_ANCHOR where exactness holds."""
    w = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=FA3Tiling(), **w)
    eng = Engine(H800, mem_fidelity="tile")
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    got = {k: st[k] for k in TILE_ANCHOR}
    assert got == TILE_ANCHOR
    assert st["dram_bytes"] == FULL_ANCHOR["dram_bytes"]
    assert st["tma_lines"] == FULL_ANCHOR["tma_lines"]
    assert abs(st["cycles"] / FULL_ANCHOR["cycles"] - 1.0) \
        <= TILE_CYCLE_ERR_MAX


def test_tile_fidelity_identity_fault_plan_bit_exact():
    """Within tile mode, attaching the identity FaultPlan must not move
    the pinned tile anchor by a single cycle or byte (the fault hooks on
    the bulk-transaction path are read-only when off)."""
    from repro.faults import FaultPlan
    w = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=FA3Tiling(), **w)
    eng = Engine(H800, mem_fidelity="tile", faults=FaultPlan.identity())
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    assert {k: st[k] for k in TILE_ANCHOR} == TILE_ANCHOR


def test_tile_fidelity_rejects_unsupported_configs():
    """tile + no-LRC machines is an explicit error (the no-LRC ablation is
    per-line request flooding by definition), as is tile + direct HBM; an
    unknown mem_fidelity never constructs an engine."""
    with pytest.raises(ValueError):
        Engine(h800_variant(lrc_enabled=False), mem_fidelity="tile")
    with pytest.raises(ValueError):
        Engine(H800, direct_hbm=True, mem_fidelity="tile")
    with pytest.raises(ValueError):
        Engine(H800, mem_fidelity="page")


def test_group_wait_counters_track_dict_bookkeeping():
    """The O(1) outstanding-group sets must reproduce the old full-dict scan,
    including the ``g <= gid`` filter: a committed group with a *higher* id
    than the wait's gid must not block it (out-of-order gid commit)."""
    results = {}
    for scheduler in SCHEDULERS:
        tr = []
        # commit high group first, then a low one; wait only on the low id
        for gid in (5, 1):
            for _ in range(3):
                tr.append(Instr(isa.WGMMA, gid=gid, m=64, n=128, k=16))
            tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
        tr.append(Instr(isa.WGMMA_WAIT, gid=1, n=0))   # ignores group 5
        tr.append(Instr(isa.WGMMA_WAIT, gid=5, n=0))   # drain everything
        eng = Engine(H800, n_sms=1, mem_scale=1.0, scheduler=scheduler)
        eng.launch([CTATrace(wgs=[tr], n_consumers=1)])
        st = eng.run()
        assert not eng.deadlocked
        assert st["tc_busy_cycles"] == 6 * 64
        results[scheduler] = st
    assert results["event"] == results["waiter"] == results["broadcast"]
