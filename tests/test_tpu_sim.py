"""TPU-mode Sim-FA (hardware adaptation): grid-pipeline traces, analytical
model, and sim-guided autotuning."""
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: degrade, don't die
from hypothesis import given, settings, strategies as st

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import TPU_V5E
from repro.core.tpu.analytical import analyze_tpu
from repro.core.tpu.autotune import autotune_flash
from repro.core.tpu.machine import mxu_cycles, tpu_engine_machine, vpu_softmax_cycles
from repro.core.tpu.tracegen import flash_grid_trace


def _w(L=1024, S=None, H_kv=2, G=2, D=128):
    return AttnWorkload(name="t", B=1, L=L, S=S or L, H_kv=H_kv, G=G, D=D,
                        causal=True)


def _sim(w, bq=128, bk=128, stages=2, **kw):
    cta, tmaps = flash_grid_trace(w, TPU_V5E, bq=bq, bk=bk, stages=stages,
                                  max_grid_rows=4, **kw)
    eng = Engine(tpu_engine_machine(TPU_V5E), n_sms=1, mem_scale=1.0,
                 direct_hbm=True)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch([cta])
    st = eng.run()
    return eng, st


def test_grid_trace_runs_without_deadlock():
    eng, st = _sim(_w())
    assert not eng.deadlocked
    assert st["cycles"] > 0


def test_deferred_pv_wait_starves_two_stage_ring():
    """§Perf refuted hypothesis (EXPERIMENTS.md): deferring the PV wait was
    expected to hide softmax, but at stages=2 the deferred V-slot release
    starves the ring buffer and REGRESSES ~20%; at stages>=3 the QK_{j+1}
    prefetch already provides the overlap and defer is neutral."""
    _, d2 = _sim(_w(L=2048), stages=2, defer_pv_wait=True)
    _, b2 = _sim(_w(L=2048), stages=2, defer_pv_wait=False)
    assert d2["cycles"] > b2["cycles"]            # the regression is real
    _, d3 = _sim(_w(L=2048), stages=3, defer_pv_wait=True)
    _, b3 = _sim(_w(L=2048), stages=3, defer_pv_wait=False)
    assert d3["cycles"] == pytest.approx(b3["cycles"], rel=0.05)


def test_more_stages_never_slower():
    """The confirmed lever: deeper ring buffers (2->4 measured ~30%)."""
    _, st2 = _sim(_w(L=2048), stages=2, defer_pv_wait=False)
    _, st3 = _sim(_w(L=2048), stages=3, defer_pv_wait=False)
    _, st4 = _sim(_w(L=2048), stages=4, defer_pv_wait=False)
    assert st3["cycles"] <= st2["cycles"]
    assert st4["cycles"] <= st3["cycles"] * 1.02
    assert st4["cycles"] < 0.8 * st2["cycles"]


def test_mxu_cycles_monotone_and_padding():
    """Chip-aggregate MXU model: cycles grow with work; sub-128 tiles pad."""
    c_full = mxu_cycles(TPU_V5E, 128, 128, 128)
    c_double = mxu_cycles(TPU_V5E, 256, 128, 128)
    assert c_double >= 2 * c_full - 1
    # a 64^3 matmul wastes most of the array: cycles do NOT drop 8x
    assert mxu_cycles(TPU_V5E, 64, 64, 64) > c_full / 8


def test_analyze_tpu_regimes():
    w = _w(L=32768, H_kv=8, G=4)
    rep = analyze_tpu(w, TPU_V5E, bq=128, bk=128)
    assert rep.flops > 0
    assert rep.hbm_bytes_real > rep.hbm_bytes_ideal
    assert rep.bottleneck in ("mxu", "hbm", "vpu")
    # larger bq -> fewer row blocks -> less KV refetch
    rep_big = analyze_tpu(w, TPU_V5E, bq=512, bk=128)
    assert rep_big.hbm_bytes_real < rep.hbm_bytes_real


def test_autotune_respects_vmem():
    w = _w(L=8192, H_kv=8, G=4)
    plan = autotune_flash(w, TPU_V5E)
    assert plan.vmem_bytes <= TPU_V5E.vmem_bytes * 0.7
    assert plan.block_q in (64, 128, 256, 512)
    assert plan.block_k in (64, 128, 256, 512)


def test_autotune_sim_agrees_with_shortlist():
    w = _w(L=2048, H_kv=2, G=2)
    plan = autotune_flash(w, TPU_V5E, use_sim=True, sim_rows=2)
    assert plan.sim_us is not None and plan.sim_us > 0


@settings(max_examples=20, deadline=None)
@given(bq=st.sampled_from([64, 128, 256]), bk=st.sampled_from([64, 128, 256]))
def test_vpu_softmax_cycles_scale(bq, bk):
    base = vpu_softmax_cycles(TPU_V5E, bq, bk)
    assert base > 0
    assert vpu_softmax_cycles(TPU_V5E, 2 * bq, bk) >= base
