"""utils/hlo.py cost model: trip-count-aware FLOPs/bytes/collectives must
match XLA ground truth where XLA is correct (unrolled) and fix it where it
is not (scanned while bodies)."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo import collective_bytes, hlo_cost, xla_cost_analysis


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, ws):
    return jax.lax.scan(_body, x, ws)[0]


def _unrolled(x, ws):
    for i in range(ws.shape[0]):
        x, _ = _body(x, ws[i])
    return x


X = jax.ShapeDtypeStruct((128, 256), jnp.float32)
WS = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
EXPECTED_FLOPS = 8 * 2 * 128 * 256 * 256


def test_flops_scan_equals_unrolled_equals_expected():
    cs = jax.jit(_scanned).lower(X, WS).compile()
    cu = jax.jit(_unrolled).lower(X, WS).compile()
    assert hlo_cost(cs.as_text())["flops"] == EXPECTED_FLOPS
    assert hlo_cost(cu.as_text())["flops"] == EXPECTED_FLOPS
    # XLA itself undercounts the scanned module (why hlo_cost exists)
    assert xla_cost_analysis(cs)["flops"] < EXPECTED_FLOPS / 2


def test_bytes_match_xla_on_unrolled():
    cu = jax.jit(_unrolled).lower(X, WS).compile()
    ours = hlo_cost(cu.as_text())["bytes"]
    xla = xla_cost_analysis(cu)["bytes accessed"]
    assert ours == pytest.approx(xla, rel=0.25)


def test_bytes_scan_counts_carry_roundtrips():
    cs = jax.jit(_scanned).lower(X, WS).compile()
    ours = hlo_cost(cs.as_text())["bytes"]
    # each of 8 iterations moves >= the weight slice (256KB) + carry
    assert ours >= 8 * (256 * 256 * 4)
    # but not the full stacked weights per iteration (slice-aware)
    assert ours < 8 * (8 * 256 * 256 * 4)


def test_attention_flops_exact():
    def attn(q, k, v):
        s = jnp.einsum("bhld,bhsd->bhls", q, k)
        return jnp.einsum("bhls,bhsd->bhld", jax.nn.softmax(s, -1), v)
    q = jax.ShapeDtypeStruct((2, 4, 128, 64), jnp.float32)
    c = jax.jit(attn).lower(q, q, q).compile()
    assert hlo_cost(c.as_text())["flops"] == 2 * (2 * 2 * 4 * 128 * 128 * 64)


def test_collective_bytes_allreduce_psum():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_collective_bytes_parses_shardmap_psum():
    # single-device: validate the parser on a hand-written HLO snippet
    hlo = """
HloModule m

ENTRY %main (p: f32[1024,256]) -> f32[1024,256] {
  %p = f32[1024,256]{1,0} parameter(0)
  ROOT %ar = f32[1024,256]{1,0} all-reduce(%p), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    out = collective_bytes(hlo)
    full = 1024 * 256 * 4
    assert out["all-reduce"] == pytest.approx(2 * full * 7 / 8)


def test_collective_inside_while_multiplied():
    hlo = """
HloModule m

%cond (t: (s32[], f32[256])) -> pred[] {
  %t = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (t: (s32[], f32[256])) -> (s32[], f32[256]) {
  %t = (s32[], f32[256]) parameter(0)
  %x = f32[256]{0} get-tuple-element(%t), index=1
  %ag = f32[256]{0} all-gather(%x), replica_groups=[1,4]<=[4], dimensions={0}
  %i = s32[] get-tuple-element(%t), index=0
  ROOT %r = (s32[], f32[256]) tuple(%i, %ag)
}

ENTRY %main (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  ROOT %w = (s32[], f32[256]) while(%p), condition=%cond, body=%body
}
"""
    out = collective_bytes(hlo)
    per = 256 * 4 * 3 / 4
    assert out["all-gather"] == pytest.approx(12 * per)
    assert out["count_all-gather"] == 12


def test_known_trip_count_preferred():
    cs = jax.jit(_scanned).lower(X, WS).compile()
    text = cs.as_text()
    assert "known_trip_count" in text   # XLA annotates canonical scans
    assert hlo_cost(text)["flops"] == EXPECTED_FLOPS
