"""simfa fidelity-tier selection and mem_fidelity propagation.

Covers the auto precedence ``full -> tile -> hierarchical`` (with the
no-LRC guard that skips the tile tier), explicit-fidelity override
semantics, the tile tier's traffic-parity contract against full, and the
``mem_fidelity`` provenance stamp on SimResult + manifest.  The per-cell
cycle/traffic error budget lives in tests/test_engine_equiv.py and
benchmarks/bench_fidelity.py; this file is about *selection*.
"""
import pytest

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import H800, h800_variant
from repro.core.simfa import (FULL_CTA_LIMIT, TILE_CTA_LIMIT, simulate_fa3)

# launches sized to land in each auto tier (CTA totals include the
# ping-pong pair factor; S kept small so the tile-tier cycle sim stays
# cheap in tier-1)
SMALL_W = AttnWorkload(name="s", B=1, L=256, S=512, H_kv=1, G=2, D=128)
MID_W = AttnWorkload(name="m", B=1, L=20480, S=128, H_kv=2, G=2, D=128)
LARGE_W = AttnWorkload(name="l", B=8, L=4096, S=256, H_kv=8, G=4, D=128)


def test_auto_precedence_small_selects_full():
    r = simulate_fa3(SMALL_W, H800)
    assert r.fidelity == "full"
    assert r.mem_fidelity == "line"
    assert r.n_ctas_total <= FULL_CTA_LIMIT
    assert r.manifest["mem_fidelity"] == "line"


def test_auto_precedence_mid_selects_tile():
    r = simulate_fa3(MID_W, H800)
    assert r.fidelity == "tile"
    assert r.mem_fidelity == "tile"
    assert FULL_CTA_LIMIT < r.n_ctas_total <= TILE_CTA_LIMIT
    # tile is a cycle-exact tier: every CTA simulated, no extrapolation
    assert r.n_ctas_simulated == r.n_ctas_total
    assert r.manifest["mem_fidelity"] == "tile"


def test_auto_precedence_large_selects_hierarchical():
    r = simulate_fa3(LARGE_W, H800)
    assert r.fidelity == "hierarchical"
    assert r.mem_fidelity == "line"
    assert r.n_ctas_total > TILE_CTA_LIMIT
    assert r.n_ctas_simulated < r.n_ctas_total


def test_auto_skips_tile_on_no_lrc_machines():
    """The tile front end refuses lrc_enabled=False (the no-LRC ablation is
    per-line request flooding by definition), so auto must route mid-size
    launches on such machines straight to hierarchical."""
    r = simulate_fa3(MID_W, h800_variant(lrc_enabled=False))
    assert r.fidelity == "hierarchical"
    assert r.mem_fidelity == "line"


def test_explicit_fidelity_is_respected():
    # explicit tile on a launch auto would run full
    r = simulate_fa3(SMALL_W, H800, fidelity="tile")
    assert r.fidelity == "tile"
    assert r.mem_fidelity == "tile"
    # explicit hierarchical on the same tiny launch
    r2 = simulate_fa3(SMALL_W, H800, fidelity="hierarchical")
    assert r2.fidelity == "hierarchical"
    assert r2.mem_fidelity == "line"


def test_explicit_engine_opts_mem_fidelity_wins():
    """fidelity="full" with an explicit engine_opts mem_fidelity runs the
    full tier on the tile memory model (the setdefault never overrides)."""
    r = simulate_fa3(SMALL_W, H800, fidelity="full",
                     engine_opts={"mem_fidelity": "tile"})
    assert r.fidelity == "full"
    assert r.mem_fidelity == "tile"
    assert r.manifest["mem_fidelity"] == "tile"


def test_unknown_fidelity_raises():
    with pytest.raises(ValueError, match="fidelity"):
        simulate_fa3(SMALL_W, H800, fidelity="approximate")


def test_explicit_tile_on_no_lrc_machine_raises():
    with pytest.raises(ValueError, match="lrc_enabled"):
        simulate_fa3(SMALL_W, h800_variant(lrc_enabled=False),
                     fidelity="tile")
    with pytest.raises(ValueError, match="lrc_enabled"):
        Engine(h800_variant(lrc_enabled=False), mem_fidelity="tile")


def test_tile_tier_traffic_parity_with_full():
    """On the same launch, the tile tier reports byte-identical DRAM/L2
    demand traffic to full (the refcounted residency contract)."""
    full = simulate_fa3(SMALL_W, H800, fidelity="full")
    tile = simulate_fa3(SMALL_W, H800, fidelity="tile")
    assert tile.dram_bytes == full.dram_bytes
    assert tile.l2_bytes == full.l2_bytes
    assert tile.l2_stats["misses"] == full.l2_stats["misses"]
    assert abs(tile.cycles / full.cycles - 1.0) <= 0.05
