"""Sharding-rule coverage on the FULL assigned configs (no compilation:
eval_shape + spec arithmetic) and elastic checkpoint resharding."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: degrade, don't die
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models import api
from repro.parallel import sharding as shd

ROOT = Path(__file__).resolve().parent.parent


class FakeMesh:
    """Duck-typed mesh: sharding rules only read .shape and .axis_names."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH_POD = FakeMesh(pod=2, data=16, model=16)


def _axis_product(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    return prod


@pytest.mark.parametrize("mesh", [MESH, MESH_POD], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_param_specs_divide_full_configs(arch, mesh):
    """Every full-size assigned config gets valid (divisible) PartitionSpecs
    on both production meshes — the invariant the dry-run relies on."""
    cfg = registry.get(arch)
    struct = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_specs(cfg, struct, mesh)

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            prod = _axis_product(mesh, entry)
            assert leaf.shape[i] % prod == 0, (
                f"{arch}: {jax.tree_util.keystr(path)} dim{i}="
                f"{leaf.shape[i]} not divisible by {entry}={prod}")

    jax.tree_util.tree_map_with_path(
        check, struct, specs,
        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))


def test_params_are_fsdp_sharded_not_replicated():
    """>=90% of parameter BYTES must shard over the fsdp axes for the big
    models (otherwise per-chip memory explodes silently)."""
    for arch in ("grok-1-314b", "command-r-plus-104b", "dbrx-132b"):
        cfg = registry.get(arch)
        struct = jax.eval_shape(lambda c=cfg: api.init(c, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, struct, MESH)
        tot, sharded = 0, 0
        for leaf, spec in zip(jax.tree.leaves(struct),
                              jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            b = leaf.size
            tot += b
            entries = [e for e in spec if e is not None]
            flat = [a for e in entries for a in (e if isinstance(e, tuple) else (e,))]
            if "data" in flat:
                sharded += b
        assert sharded / tot > 0.9, f"{arch}: only {sharded/tot:.0%} FSDP-sharded"


@settings(max_examples=80, deadline=None)
@given(dim=st.integers(1, 10_000), ax=st.sampled_from(
    [("data",), ("model",), ("data", "model"), None]))
def test_sanitize_spec_always_valid(dim, ax):
    spec = P(ax if ax is None or len(ax) > 1 else ax[0])
    out = shd.sanitize_spec(spec, (dim,), MESH)
    entry = out[0] if len(out) else None
    if entry is not None:
        assert dim % _axis_product(MESH, entry) == 0


def test_elastic_reshard_restore(tmp_path):
    """FT contract: a checkpoint written under one mesh restores onto a
    DIFFERENT mesh layout with identical values (elastic scale-up/down)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.manager import CheckpointManager

        out = sys.argv[1]
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)

        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        mgr = CheckpointManager(out, async_save=False)
        mgr.save(1, {"w": wa})

        # restore onto a re-shaped mesh (4x2) with transposed layout
        mesh_b = jax.make_mesh((4, 2), ("data", "model"))
        sh_b = {"w": NamedSharding(mesh_b, P("model", "data"))}
        restored = mgr.restore(1, {"w": wa}, shardings=sh_b)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.is_equivalent_to(sh_b["w"], 2)
        print("ELASTIC_OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", script, str(tmp_path / "ck")],
        cwd=ROOT, timeout=300, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
