"""Observability subsystem tests (obs/): conservation, schema, provenance.

1. **Counter conservation** — the windowed PM-counter timelines must
   integrate exactly to the engine's end-of-run totals: DRAM bytes per
   window sum to ``SimResult.dram_bytes``, per-SM tensor-core busy sums to
   ``tc_busy_cycles``, sampled ring occupancy never exceeds the declared
   stage depth.  Holds regardless of sampling cadence because samples are
   cumulative-counter snapshots (telescoping sums).
2. **Trace-export schema** — the Perfetto/Chrome ``trace_event`` JSON is
   valid, ``ts`` is monotonic per thread, every ``s``/``f`` flow arrow and
   ``b``/``e`` async pair is matched, for all four registered kernels.
3. **Provenance** — manifest hashing/host matching, ``save_json``
   stamping, sweep-cache back-compat with pre-manifest bare-list files.

Bit-neutrality of the sink itself is enforced in ``test_engine_equiv.py``.
"""
import json

import pytest

from repro.configs.llama3 import AttnWorkload
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3
from repro.obs import (build_manifest, build_report, config_hash,
                       export_trace, host_fingerprint, render_report,
                       role_stall_timelines, same_host,
                       subsystem_wall_breakdown)
from repro.obs.labels import (cta_of, label_of, lane_of, make_label, role_of,
                              split_gantt_tag, split_label)

W_SMALL = AttnWorkload(name="obs-small", B=1, L=256, S=512, H_kv=1, G=2,
                       D=128)

# one small full-fidelity FA3 workload per registered kernel (decode shape
# for split-KV), kept tiny so the full grid stays tier-1 fast
KERNEL_WORKLOADS = {
    "fa3": W_SMALL,
    "fa3_cooperative": W_SMALL,
    "fa2": AttnWorkload(name="obs-fa2", B=1, L=192, S=384, H_kv=1, G=1,
                        D=64),
    "splitkv_decode": AttnWorkload(name="obs-decode", B=2, L=1, S=2048,
                                   H_kv=2, G=4, D=128),
}


@pytest.fixture(scope="module")
def res():
    """One recorded full-fidelity FA3 run shared by the conservation and
    report tests."""
    return simulate_fa3(W_SMALL, H800, fidelity="full", record_events=True,
                        record_counters=True, counter_window=128)


# ---------------------------------------------------------------------------
# counter conservation
# ---------------------------------------------------------------------------

def test_dram_timeline_integrates_to_total(res):
    snk = res.counters
    integral = sum(db for _, _, db in snk.dram_bytes_per_window())
    assert integral == res.dram_bytes == snk.totals["dram_bytes"]


def test_tc_busy_integrates_to_engine_total(res):
    snk = res.counters
    total = sum(busy for _, _, busy in snk.tc_busy_per_window())
    assert total == snk.totals["tc_busy_cycles"]
    # per-SM series telescope to their own finals too
    for sm_id, series in snk.tc_busy.items():
        assert sum(b for _, _, b in snk.tc_busy_per_window(sm_id)) \
            == series[-1]


def test_tma_lines_integrate_to_total(res):
    snk = res.counters
    assert snk.tma_lines[-1] == snk.totals["tma_lines"]


def test_ring_occupancy_bounded_by_declared_depth(res):
    snk = res.counters
    assert snk.ring_occupancy, "kernel-IR ring metadata never reached sink"
    for key, series in snk.ring_occupancy.items():
        declared = snk.ring_depths[key]
        for _, depth in series:
            assert 0 <= depth <= declared, (key, depth, declared)
    for key, peak in snk.ring_max_depths().items():
        assert peak <= snk.ring_depths[key]


def test_derived_rates_are_fractions(res):
    snk = res.counters
    assert all(0.0 <= u <= 1.0 for _, u in snk.dram_util_timeline())
    assert all(0.0 <= r <= 1.0 for _, r in snk.l2_hit_rate_timeline())
    limit = H800.num_sms * H800.occupancy_limit
    assert 0.0 < snk.avg_resident_ctas() <= limit
    assert all(n >= 0 for _, n in snk.tma_inflight_timeline())


def test_stall_timelines_sum_to_attribution_totals(res):
    """The windowed per-role stall timelines are an exact re-binning of the
    DAG stall attribution, not an approximation."""
    from repro.analysis import dag as dag_mod
    from repro.analysis.critical_path import attribute_stalls

    tl = role_stall_timelines(res.trace, window=128)
    sr = attribute_stalls(dag_mod.build(res.trace.events,
                                        res.trace.dispatch_parent))
    want = sr.by_role()
    for role, buckets in tl.items():
        for bucket, wins in buckets.items():
            assert sum(wins.values()) == pytest.approx(
                want[role][bucket], abs=1e-6), (role, bucket)


# ---------------------------------------------------------------------------
# trace export schema
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", sorted(KERNEL_WORKLOADS))
def test_trace_export_schema(kernel, tmp_path):
    r = simulate_fa3(KERNEL_WORKLOADS[kernel], H800, fidelity="full",
                     record_events=True, record_counters=True,
                     kernel=kernel)
    path = tmp_path / f"{kernel}.trace.json"
    export_trace(str(path), r.trace, r.counters, r.manifest, name=kernel)

    obj = json.loads(path.read_text())          # valid JSON round-trip
    evs = obj["traceEvents"]
    assert obj["otherData"]["manifest"]["kernel"] == kernel
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)
    assert any(e["ph"] == "C" for e in evs), "no counter tracks exported"

    last_ts = {}
    flows = {}
    async_open = {}
    for e in evs:
        if e["ph"] == "M":
            continue
        tid = e.get("tid", 0)
        assert e["ts"] >= last_ts.get(tid, 0), "ts not monotonic per tid"
        last_ts[tid] = e["ts"]
        if e["ph"] in ("s", "f"):
            flows.setdefault((e["cat"], e["id"], e["name"]), []).append(
                e["ph"])
        elif e["ph"] in ("b", "e"):
            async_open[(e["cat"], e["id"])] = \
                async_open.get((e["cat"], e["id"]), 0) + \
                (1 if e["ph"] == "b" else -1)
    assert flows, "no flow arrows exported"
    for key, phases in flows.items():
        assert sorted(phases) == ["f", "s"], f"unmatched flow {key}"
    assert async_open and all(v == 0 for v in async_open.values()), \
        "unbalanced b/e async slices"


def test_trace_export_counters_only(tmp_path):
    """A trace with just counter tracks (no PipeEvents) is still valid."""
    r = simulate_fa3(W_SMALL, H800, fidelity="full", record_counters=True)
    obj = export_trace(str(tmp_path / "c.json"), None, r.counters)
    assert any(e["ph"] == "C" for e in obj["traceEvents"])
    assert not any(e["ph"] in ("s", "f") for e in obj["traceEvents"])


# ---------------------------------------------------------------------------
# label convention (the gantt/critical_path dedupe)
# ---------------------------------------------------------------------------

def test_label_roundtrip_and_roles():
    assert make_label(3, "consumer1") == "cta3/consumer1"
    assert split_label("cta3/consumer1") == (3, "consumer1")
    assert cta_of("cta12/producer") == 12
    assert cta_of("freeform") is None
    assert role_of("cta3/consumer1") == "consumer"
    assert role_of("cta0/producer") == "producer"
    assert role_of("cta0/wg2") == "wg"
    assert split_gantt_tag("mma:cta0/consumer1:QK") == \
        ("mma", "cta0/consumer1", "QK")
    assert lane_of("tma:cta1/producer:K3") == "tma"
    assert label_of("bubble:cta1/consumer0") == "cta1/consumer0"


def test_gantt_and_critical_path_share_label_parser():
    from repro.analysis import critical_path
    from repro.core import gantt

    assert gantt.lane_of is lane_of
    assert critical_path.role_of is role_of


# ---------------------------------------------------------------------------
# manifests + stamping
# ---------------------------------------------------------------------------

def test_manifest_hashes_and_host_identity(res):
    m = build_manifest(machine=H800, workload=W_SMALL, kernel="fa3",
                       scheduler="event", wall_s=0.5, sim_cycles=1000,
                       events_popped=100)
    assert m["machine_hash"] == config_hash(H800)        # deterministic
    assert m["workload_hash"] == config_hash(W_SMALL)
    assert m["cycles_per_s"] == 2000.0
    assert m["host_id"] == host_fingerprint()
    assert same_host(m, res.manifest)                    # this very host
    assert not same_host(m, None) and not same_host(None, m)
    assert not same_host(m, {"host_id": "ffffffffffff"})


def test_simresult_carries_manifest(res):
    m = res.manifest
    assert m["kernel"] == "fa3" and m["fidelity"] == "full"
    assert m["sim_cycles"] == int(res.cycles)
    assert m["counter_window"] == 128
    assert m["wall_s"] > 0 and m["events_per_s"] > 0


def test_save_json_stamps_manifest(tmp_path):
    from repro.analysis.report import save_json

    p1 = tmp_path / "d.json"
    save_json(str(p1), {"x": 1})
    got = json.loads(p1.read_text())
    assert got["x"] == 1 and "git_sha" in got["manifest"]

    p2 = tmp_path / "l.json"
    save_json(str(p2), [{"x": 1}])
    got = json.loads(p2.read_text())
    assert got["rows"] == [{"x": 1}] and "manifest" in got

    p3 = tmp_path / "raw.json"
    save_json(str(p3), {"x": 1}, manifest=False)
    assert json.loads(p3.read_text()) == {"x": 1}


def test_sweep_cache_reads_legacy_and_stamped(tmp_path):
    """Pre-manifest bare-list cache files and stamped ones both round-trip
    through ``run_sweep`` without re-simulating."""
    from repro.analysis.sweep import SweepPoint, _key, knob_grid, run_sweep

    grid = knob_grid()
    point = SweepPoint(workload=W_SMALL, machine=H800)
    marker = [{"workload": "cached", "speedup": 1.0}]

    legacy = tmp_path / f"whatif_{_key(point, grid)}.json"
    legacy.write_text(json.dumps(marker))               # bare-list (legacy)
    assert run_sweep([point], grid, cache_dir=str(tmp_path)) == marker

    legacy.write_text(json.dumps({"manifest": {"git_sha": "x"},
                                  "rows": marker}))     # stamped
    assert run_sweep([point], grid, cache_dir=str(tmp_path)) == marker


def test_subsystem_wall_breakdown_shape():
    result, breakdown = subsystem_wall_breakdown(
        simulate_fa3, KERNEL_WORKLOADS["fa2"], H800, fidelity="full")
    assert result.cycles > 0
    assert breakdown and all(v >= 0 for v in breakdown.values())
    assert "core.engine" in breakdown       # the run loop always shows up


# ---------------------------------------------------------------------------
# NCU-style report
# ---------------------------------------------------------------------------

def test_report_sections_and_render(res):
    rep = build_report(res, H800, workload=W_SMALL, manifest=res.manifest)
    sol = rep["speed_of_light"]
    assert 0 < sol["sol_pct"] <= 100.0
    assert sol["sol_pct"] == max(sol["dram_pct"], sol["l2_pct"],
                                 sol["tensorcore_pct"])
    assert rep["occupancy"]["pct"] > 0
    assert rep["rings"] and all(r["peak_depth"] <= r["declared"]
                                for r in rep["rings"].values())
    assert set(rep["stalls"]["buckets"]) >= {"tma-wait", "barrier-wait"}
    txt = render_report(rep)
    assert "speed of light" in txt and "stall breakdown" in txt
    assert res.manifest["git_sha"] in txt
