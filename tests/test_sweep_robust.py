"""Crash-proof sweep harness (docs/robustness.md): per-point worker
processes, kill-on-timeout, retry with exponential backoff, incremental
atomic cache flush, corrupt-cache quarantine.

Workers are injected via ``run_sweep(worker=...)`` and coordinate through
marker files in a tmp dir (passed by env var so they survive any
multiprocessing start method): ``try_<point>_<n>`` counts attempts, so the
tests can assert *how many times* a point ran, not just that it finished.
"""
import json
import os
import time

import pytest

from repro.analysis.sweep import (
    SweepError,
    SweepPoint,
    _cache_path,
    knob_grid,
    run_sweep,
)
from repro.configs.llama3 import AttnWorkload
from repro.core.machine import H800
from repro.utils.ioutil import atomic_write_json

GRID = knob_grid(tma_bw=(1.0, 2.0))


def _points(n=2):
    return [SweepPoint(workload=AttnWorkload(name=f"w{i}", B=1, L=64, S=128,
                                             H_kv=1, G=1, D=64),
                       machine=H800)
            for i in range(n)]


# -- injected workers (module-level: picklable under any start method) ------

def _mark(point, tag) -> int:
    """Drop a marker file for this (tag, point) attempt; return how many
    attempts happened *before* this one."""
    d = os.environ["SWEEP_TEST_DIR"]
    pre = f"{tag}_{point.workload.name}_"
    n = len([f for f in os.listdir(d) if f.startswith(pre)])
    with open(os.path.join(d, pre + str(n)), "w") as f:
        f.write(str(os.getpid()))
    return n


def _marks(tmp_path, tag, point) -> int:
    pre = f"{tag}_{point.workload.name}_"
    return len([f for f in os.listdir(tmp_path) if f.startswith(pre)])


def _ok_worker(args):
    point, grid = args
    _mark(point, "ok")
    return [{"workload": point.workload.name, "knobs_label": k.label(),
             "speedup": 1.0} for k in grid]


def _crash_once_worker(args):
    point, grid = args
    if _mark(point, "try") == 0:
        os._exit(3)          # simulated OOM kill: no exception, no rows
    return _ok_worker(args)


def _selective_crash_worker(args):
    point, grid = args
    if point.workload.name == "w1":
        os._exit(9)
    return _ok_worker(args)


def _raise_once_worker(args):
    point, grid = args
    if _mark(point, "ser") == 0:
        raise RuntimeError("flaky")
    return _ok_worker(args)


def _raise_worker(args):
    raise RuntimeError("boom")


def _hang_worker(args):
    time.sleep(60)


@pytest.fixture
def sweep_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("SWEEP_TEST_DIR", str(tmp_path))
    return tmp_path


# ---------------------------------------------------------------------------

def test_crashed_worker_retried_with_backoff_and_recovers(sweep_dir):
    """Every point's first worker dies with ``os._exit`` (the OOM-kill
    shape: pipe EOF, no traceback); the retry must recover both points and
    flush both cache files."""
    cache = sweep_dir / "cache"
    points = _points(2)
    t0 = time.monotonic()
    rows = run_sweep(points, GRID, processes=2, cache_dir=str(cache),
                     retries=2, backoff_s=0.05, worker=_crash_once_worker)
    elapsed = time.monotonic() - t0
    assert len(rows) == len(points) * len(GRID)
    for p in points:
        assert _marks(sweep_dir, "try", p) == 2      # crash + successful retry
        path = _cache_path(str(cache), p, GRID)
        assert os.path.exists(path)
        with open(path) as f:
            payload = json.load(f)                   # flushed file is whole
        assert len(payload["rows"]) == len(GRID)
        assert "manifest" in payload
    assert elapsed >= 0.05       # the backoff stamp was honored


def test_completed_points_flushed_before_sweep_error(sweep_dir):
    """One point failing permanently raises SweepError — but only after the
    healthy point's rows hit the cache, so the re-run pays for one point."""
    cache = sweep_dir / "cache"
    points = _points(2)
    with pytest.raises(SweepError, match="w1"):
        run_sweep(points, GRID, processes=2, cache_dir=str(cache),
                  retries=1, backoff_s=0.01, worker=_selective_crash_worker)
    assert os.path.exists(_cache_path(str(cache), points[0], GRID))
    assert not os.path.exists(_cache_path(str(cache), points[1], GRID))
    # re-run with a healthy worker: w0 served from cache (no new attempt)
    rows = run_sweep(points, GRID, processes=2, cache_dir=str(cache),
                     worker=_ok_worker)
    assert len(rows) == len(points) * len(GRID)
    assert _marks(sweep_dir, "ok", points[0]) == 1   # cached, not recomputed
    assert _marks(sweep_dir, "ok", points[1]) == 1   # computed in the re-run


def test_corrupt_cache_quarantined_and_recomputed(sweep_dir):
    cache = sweep_dir / "cache"
    points = _points(2)
    cache.mkdir()
    bad = _cache_path(str(cache), points[0], GRID)
    with open(bad, "w") as f:
        f.write('{"manifest": {"git_sha": "x"}, "rows": [{"tr')   # torn write
    rows = run_sweep(points, GRID, processes=1, cache_dir=str(cache),
                     worker=_ok_worker)
    assert len(rows) == len(points) * len(GRID)
    assert os.path.exists(bad + ".corrupt")          # inspectable, not re-read
    assert _marks(sweep_dir, "ok", points[0]) == 1   # recomputed once
    # the rewritten cache is valid: a second sweep computes nothing
    run_sweep(points, GRID, processes=1, cache_dir=str(cache),
              worker=_ok_worker)
    assert _marks(sweep_dir, "ok", points[0]) == 1
    assert _marks(sweep_dir, "ok", points[1]) == 1


def test_hung_worker_killed_at_timeout(sweep_dir):
    t0 = time.monotonic()
    with pytest.raises(SweepError, match="timed out"):
        run_sweep(_points(1), GRID, processes=2, timeout_s=0.3, retries=1,
                  backoff_s=0.05, worker=_hang_worker)
    # 2 attempts x 0.3 s + backoff, not 60 s of sleep
    assert time.monotonic() - t0 < 20


def test_serial_mode_retries_exceptions(sweep_dir):
    points = _points(1)
    rows = run_sweep(points, GRID, processes=1, retries=1, backoff_s=0.01,
                     worker=_raise_once_worker)
    assert len(rows) == len(GRID)
    assert _marks(sweep_dir, "ser", points[0]) == 2


def test_serial_mode_permanent_failure_raises(sweep_dir):
    with pytest.raises(SweepError, match="boom"):
        run_sweep(_points(1), GRID, processes=1, retries=1, backoff_s=0.01,
                  worker=_raise_worker)


# ---------------------------------------------------------------------------
# atomic artifact writes (repro.utils.ioutil)
# ---------------------------------------------------------------------------

def test_atomic_write_json_replaces_whole_file(tmp_path):
    path = str(tmp_path / "artifact.json")
    atomic_write_json(path, {"v": 1})
    atomic_write_json(path, {"v": 2})
    with open(path) as f:
        assert json.load(f) == {"v": 2}
    assert [p.name for p in tmp_path.iterdir()] == ["artifact.json"]


def test_atomic_write_failure_leaves_old_artifact_intact(tmp_path):
    path = str(tmp_path / "artifact.json")
    atomic_write_json(path, {"v": 1})
    with pytest.raises(TypeError):
        atomic_write_json(path, {"v": {1, 2}})       # sets aren't JSON
    with open(path) as f:
        assert json.load(f) == {"v": 1}              # untouched
    assert [p.name for p in tmp_path.iterdir()] == ["artifact.json"]
