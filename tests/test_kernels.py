"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: degrade, don't die
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.models import attention

KEY = jax.random.PRNGKey(0)


def _tol(dt):
    return dict(atol=2e-2, rtol=2e-2) if dt == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,Hkv,L,S,D", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 2, 256, 256, 128),
    (2, 4, 4, 100, 100, 64),      # non-multiple of block
    (1, 4, 1, 64, 384, 128),      # cross(L != S)
    (1, 2, 2, 192, 192, 112),     # zamba head_dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_matches_ref(B, H, Hkv, L, S, D, causal, dtype):
    if causal and L != S:
        pytest.skip("causal path assumes aligned self-attention")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, L, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                        interpret=True)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,S,D,clen", [
    (2, 8, 2, 512, 64, 300),
    (1, 16, 8, 1024, 128, 1024),
    (2, 4, 4, 256, 64, 1),
    (1, 6, 1, 640, 128, 77),      # G=6 (dbrx-like), ragged length
])
@pytest.mark.parametrize("partials", [False, True])
def test_flash_decode_matches_ref(B, H, Hkv, S, D, clen, partials):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, Hkv, S, D))
    vc = jax.random.normal(ks[2], (B, Hkv, S, D))
    o_ref = ref.flash_decode_ref(q, kc, vc, jnp.full((B,), clen))
    if partials:
        acc, m, l = flash_decode(q, kc, vc, clen, block_k=128,
                                 return_partials=True, interpret=True)
        o = acc / jnp.maximum(l, 1e-30)[..., None]
    else:
        o = flash_decode(q, kc, vc, clen, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=2e-5, rtol=2e-5)


def test_block_size_invariance():
    """Online softmax result must not depend on the tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_ops_reference_path_matches_kernel():
    """ops.mha_forward('reference') == ops.mha_forward('interpret')."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))     # model layout (B,L,H,D)
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    o1 = ops.mha_forward(q, k, v, causal=True, mode="reference")
    o2 = ops.mha_forward(q, k, v, causal=True, mode="interpret", block_q=64,
                         block_k=64)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-5, rtol=1e-5)


def test_decode_partial_merge_distributed_equivalence():
    """Sharded (o,m,l) partials merged across 4 sequence shards == global."""
    ks = jax.random.split(KEY, 3)
    B, H, Hkv, S, D = 2, 8, 4, 512, 64
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    clen = 400
    o_ref = ops.decode_forward(q, kc, vc, clen, mode="reference")
    parts = []
    for i in range(4):
        sl = slice(i * S // 4, (i + 1) * S // 4)
        valid = (jnp.arange(S)[sl][None, :] < clen) & jnp.ones((B, 1), bool)
        o, m, l = attention.decode_attend_partial(q, kc[:, sl], vc[:, sl], valid)
        parts.append((o, m, l))
    o = attention.merge_partial_attn(
        jnp.stack([p[0] for p in parts]), jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]))
    np.testing.assert_allclose(np.asarray(o[:, 0].reshape(B, 1, H, D)),
                               np.asarray(o_ref, np.float32), atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    L=st.sampled_from([64, 96, 128, 160]),
    H=st.sampled_from([2, 4]),
    G=st.sampled_from([1, 2]),
    D=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_fwd_property(L, H, G, D, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, H * G, L, D))
    k = jax.random.normal(ks[1], (1, H, L, D))
    v = jax.random.normal(ks[2], (1, H, L, D))
    o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)
    # property: rows are convex combinations of V rows -> bounded by V range
    assert float(jnp.max(jnp.abs(o))) <= float(jnp.max(jnp.abs(v))) + 1e-4


@settings(max_examples=15, deadline=None)
@given(
    S=st.sampled_from([128, 256, 384]),
    clen=st.integers(1, 384),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_decode_property(S, clen, seed):
    clen = min(clen, S)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 64))
    kc = jax.random.normal(ks[1], (1, 2, S, 64))
    vc = jax.random.normal(ks[2], (1, 2, S, 64))
    o = flash_decode(q, kc, vc, clen, block_k=128, interpret=True)
    o_ref = ref.flash_decode_ref(q, kc, vc, jnp.full((1,), clen))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)
