"""Examples must actually run (reduced knobs) — including the preemption /
restart cycle of the e2e trainer and the benchmark runner plumbing."""
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess e2e examples: minutes, not tier-1

ROOT = Path(__file__).resolve().parent.parent
ENV_PY = [sys.executable]


def _run(args, timeout=600):
    return subprocess.run(
        ENV_PY + args, cwd=ROOT, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        capture_output=True, text=True)


def test_quickstart_runs():
    r = _run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "quickstart OK" in r.stdout


def test_train_e2e_preempt_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    base = ["examples/train_e2e.py", "--arch", "qwen2.5-3b", "--steps", "24",
            "--batch", "2", "--seq", "32", "--ckpt-every", "8",
            "--ckpt-dir", ck, "--log-every", "8"]
    r1 = _run(base + ["--preempt-at", "10"])
    assert r1.returncode == 17, r1.stderr[-2000:]
    assert "simulated preemption" in r1.stdout
    r2 = _run(base)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from checkpoint step 8" in r2.stdout
    assert "done: 24 steps" in r2.stdout


def test_serve_e2e_runs():
    r = _run(["examples/serve_e2e.py", "--requests", "5", "--slots", "2",
              "--max-new", "3", "--prompt-len", "8", "--max-seq", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "serve_e2e OK" in r.stdout


def test_simulate_dse_runs():
    r = _run(["examples/simulate_dse.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DRAM traffic regimes" in r.stdout
    assert "flash block sizes" in r.stdout
