"""Fault-tolerance substrate: checkpoint manager + trainer semantics +
serving engine + data-pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import registry
from repro.data.synthetic import DataIterator, token_batch
from repro.serve.engine import Request, ServeEngine
from repro.train import trainer
from repro.train.optimizer import OptConfig, schedule_lr

KEY = jax.random.PRNGKey(0)


def _cfg():
    return registry.get("olmo-1b").reduced()


def _run_cfg(**kw):
    return trainer.RunConfig(
        opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50), **kw)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.all_steps() == [20, 30]          # retention pruned step 10
    step, restored = mgr.restore_latest(tree)
    assert step == 30
    np.testing.assert_array_equal(restored["a"], np.arange(6).reshape(2, 3) + 30)


def test_ckpt_async_save_publishes_atomically(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3, async_save=True)
    tree = {"w": jnp.ones((64, 64))}
    mgr.save(1, tree)
    mgr.wait()
    assert (tmp_path / "step_1" / "manifest.json").exists()
    assert not list(tmp_path.glob("*.tmp"))


def test_ckpt_restore_validates_structure(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, {"w": jnp.ones(3)})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.ones(3), "extra": jnp.ones(1)})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.ones(5)})


def test_ckpt_restore_to_new_sharding(tmp_path):
    """Elastic restore: same bytes, different target placement."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    src = {"w": jnp.arange(8.0)}
    mgr.save(2, src)
    shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = mgr.restore(2, src, shardings={"w": shard})
    np.testing.assert_array_equal(out["w"], np.arange(8.0))


def test_train_resume_bit_identical(tmp_path):
    """ckpt+restart at step k must equal an uninterrupted run (state and
    data order) — the preemption-recovery contract."""
    cfg = _cfg()
    run = _run_cfg(microbatches=1, remat="none")
    step_fn = jax.jit(trainer.make_train_step(cfg, run))

    def batches(start):
        return DataIterator(cfg, batch=4, seq=16, start_step=start)

    # uninterrupted 6 steps
    s_a = trainer.init_state(cfg, run, KEY)
    it = batches(0)
    for _ in range(6):
        s_a, _ = step_fn(s_a, {k: jnp.asarray(v) for k, v in next(it).items()})

    # interrupted at 3 + resumed
    s_b = trainer.init_state(cfg, run, KEY)
    it = batches(0)
    for _ in range(3):
        s_b, _ = step_fn(s_b, {k: jnp.asarray(v) for k, v in next(it).items()})
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(3, s_b)
    _, s_b2 = mgr.restore_latest(s_b)
    it2 = batches(3)                       # stateless data resume
    for _ in range(3):
        s_b2, _ = step_fn(s_b2, {k: jnp.asarray(v) for k, v in next(it2).items()})

    for a, b in zip(jax.tree.leaves(s_a.params), jax.tree.leaves(s_b2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# trainer semantics
# ---------------------------------------------------------------------------

def test_microbatch_equivalence():
    """4 microbatches produce the same loss and accumulated-gradient norm
    as 1 (first-step Adam updates are ill-conditioned near g~0, so the
    contract is on the gradients, not the post-Adam params)."""
    cfg = _cfg()
    batch = {k: jnp.asarray(v) for k, v in
             token_batch(cfg, batch=8, seq=16, step=0).items()}
    outs = {}
    for mb in (1, 4):
        run = _run_cfg(microbatches=mb, remat="none")
        state = trainer.init_state(cfg, run, KEY)
        step = jax.jit(trainer.make_train_step(cfg, run))
        new, m = step(state, batch)
        outs[mb] = (float(m["loss"]), float(m["grad_norm"]))
    assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-5)
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-3)


def test_int8_grad_compression_error_feedback():
    """Quantize->dequantize identity: deq + residual == input exactly, the
    residual feeds back, and over repeated steps the accumulated update of a
    constant gradient converges to the exact sum (the EF guarantee)."""
    from repro.train.trainer import _quantize_int8
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)
    err = jnp.zeros_like(g)
    deq, err2 = _quantize_int8(g, err)
    np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    # EF convergence: sum of dequantized updates -> n * g
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 16
    for _ in range(n):
        deq, err = _quantize_int8(g, err)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               rtol=0, atol=float(jnp.max(jnp.abs(g))) / 127)

    # and the trainer wires it: state carries a nonzero residual
    cfg = _cfg()
    run = _run_cfg(microbatches=1, remat="none", grad_compress="int8")
    state = trainer.init_state(cfg, run, KEY)
    step = jax.jit(trainer.make_train_step(cfg, run))
    it = DataIterator(cfg, batch=4, seq=16)
    state, m = step(state, {k: jnp.asarray(v) for k, v in next(it).items()})
    ef_norm = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(state.ef_error))
    assert ef_norm > 0


def test_remat_matches_no_remat():
    cfg = _cfg()
    batch = {k: jnp.asarray(v) for k, v in
             token_batch(cfg, batch=2, seq=16, step=0).items()}
    grads = {}
    for remat in ("none", "full"):
        run = _run_cfg(microbatches=1, remat=remat)
        loss_fn = trainer.make_loss_fn(cfg, run)
        state = trainer.init_state(cfg, run, KEY)
        (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
        grads[remat] = g
    for a, b in zip(jax.tree.leaves(grads["none"]), jax.tree.leaves(grads["full"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_wsd_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd",
                    wsd_stable_frac=0.8, min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, s)) for s in range(101)]
    assert lrs[5] == pytest.approx(0.5)               # warmup
    assert lrs[50] == pytest.approx(1.0)              # stable plateau
    assert lrs[100] == pytest.approx(0.1, abs=0.02)   # decayed to min
    assert all(a >= b - 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # monotone


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_batched_requests():
    cfg = _cfg()
    from repro.models import api
    params = api.init(cfg, KEY)
    eng = ServeEngine(cfg, params, slots=2, max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                    max_new=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    assert all(len(r.out) == 3 for r in reqs)
    assert eng.steps < 200


def test_data_pipeline_deterministic_and_sharded():
    cfg = _cfg()
    a = token_batch(cfg, batch=4, seq=32, step=7, seed=3)
    b = token_batch(cfg, batch=4, seq=32, step=7, seed=3)
    c = token_batch(cfg, batch=4, seq=32, step=8, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab_size
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
