"""Sim-FA engine unit tests: barrier semantics, async engines, memory
hierarchy mechanisms (paper §4, Table 3/5)."""
import pytest

from repro.core import isa
from repro.core.engine import CTATrace, Engine
from repro.core.isa import Instr, TensorMap
from repro.core.machine import H800, h800_variant
from repro.core.memory import EventQueue, build_memory


def _run(ctas, tmaps=None, cfg=H800, n_sms=1, **kw):
    eng = Engine(cfg, n_sms=n_sms, mem_scale=1.0, **kw)
    for tm in (tmaps or {}).values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return eng, st


def _tmap(map_id=0, rows=4, cols=64, esz=2):
    # rows x cols tile in a big contiguous tensor
    return TensorMap(map_id, 0, (1, 1 << 16, cols),
                     (1 << 34, cols * esz, esz), (1, rows, cols), esz)


# ---------------------------------------------------------------------------
# barriers / async semantics
# ---------------------------------------------------------------------------

def test_mb_wait_blocks_until_tma_completes():
    tm = _tmap()
    prod = [Instr(isa.TMA_TENSOR, map_id=0, sid=0, origin=(0, 0, 0))]
    cons = [Instr(isa.MB_WAIT, sid=0), Instr(isa.BUBBLES, cycles=10)]
    eng, st = _run([CTATrace(wgs=[prod, cons], n_consumers=1)], {0: tm})
    assert not eng.deadlocked
    # must include TMA setup + L2 round trip, not just the bubble
    assert st["cycles"] > H800.tma_launch_latency + H800.l2_near_latency


def test_mb_wait_without_signal_deadlocks():
    cons = [Instr(isa.MB_WAIT, sid=7)]
    eng, st = _run([CTATrace(wgs=[cons], n_consumers=1)])
    assert eng.deadlocked


def test_wgmma_wait_group_semantics():
    """WGMMA_WAIT gid N blocks until <= N committed groups outstanding."""
    tr = []
    for gid in (0, 1):
        for _ in range(4):
            tr.append(Instr(isa.WGMMA, gid=gid, m=64, n=128, k=16))
        tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
    tr.append(Instr(isa.WGMMA_WAIT, gid=1, n=0))   # drain all
    eng, st = _run([CTATrace(wgs=[tr], n_consumers=1)])
    assert not eng.deadlocked
    # 8 MMAs of N=128 at ~N/2 cycles on one pipeline ≈ 512+
    assert st["tc_busy_cycles"] == 8 * 64


def test_pingpong_barrier_orders_consumers():
    """BAR_WAIT k blocks until >= k arrivals (asymmetric named barrier)."""
    c1 = [Instr(isa.BAR_ARRIVE, bid=0), Instr(isa.BUBBLES, cycles=50)]
    c2 = [Instr(isa.BAR_WAIT, bid=0, n=1), Instr(isa.BUBBLES, cycles=50)]
    eng, st = _run([CTATrace(wgs=[c1, c2], n_consumers=2)])
    assert not eng.deadlocked


def test_producer_consumer_ring_buffer_backpressure():
    """ACQUIRE_STAGE blocks the producer until consumers release the slot."""
    tm = _tmap()
    stages = 2
    n_tiles = 5
    prod, cons = [], []
    for j in range(n_tiles):
        sid = j % stages
        prod.append(Instr(isa.ACQUIRE_STAGE, sid=sid))
        prod.append(Instr(isa.TMA_TENSOR, map_id=0, sid=sid, origin=(0, j * 4, 0)))
    for j in range(n_tiles):
        sid = j % stages
        cons.append(Instr(isa.MB_WAIT, sid=sid))
        cons.append(Instr(isa.BUBBLES, cycles=200))
        cons.append(Instr(isa.RELEASE_STAGE, sid=sid))
    eng, st = _run([CTATrace(wgs=[prod, cons], n_consumers=1)], {0: tm})
    assert not eng.deadlocked
    # consumer serializes 5 tiles x 200-cycle bubbles minimum
    assert st["cycles"] >= 1000


def test_tma_store_group_wait():
    tm = _tmap()
    tr = [Instr(isa.TMA_STORE, map_id=0, gid=3, origin=(0, 0, 0)),
          Instr(isa.TMA_COMMIT, gid=3),
          Instr(isa.TMA_WAIT, gid=3, n=0)]
    eng, st = _run([CTATrace(wgs=[tr], n_consumers=1)], {0: tm})
    assert not eng.deadlocked


# ---------------------------------------------------------------------------
# TMA engine mechanics
# ---------------------------------------------------------------------------

def test_tma_dedup_reduces_requests():
    """Per-element address generation floods the memory system (Table 5)."""
    tm = _tmap(rows=8, cols=64)          # 64 elems fp16 = 1 line per row
    lines_dedup = tm.tile_lines((0, 0, 0), 128, dedup=True)
    lines_elem = tm.tile_lines((0, 0, 0), 128, dedup=False)
    assert len(lines_dedup) == 8
    assert len(lines_elem) == 8 * 64     # one request per element
    assert set(lines_elem) == set(lines_dedup)


def test_bulk_skips_descriptor_setup():
    tm = _tmap()
    def total(bulk):
        tr = [Instr(isa.TMA_TENSOR, map_id=0, sid=0, origin=(0, 0, 0),
                    bulk=bulk),
              Instr(isa.MB_WAIT, sid=0)]
        _, st = _run([CTATrace(wgs=[tr], n_consumers=1)], {0: tm})
        return st["cycles"]
    assert total(False) - total(True) == H800.tma_tmap_setup_latency


def test_inflight_line_cap_throttles():
    cfg_small = h800_variant(tma_max_inflight_lines=2)
    tm = _tmap(rows=64, cols=64)
    tr = [Instr(isa.TMA_TENSOR, map_id=0, sid=0, origin=(0, 0, 0)),
          Instr(isa.MB_WAIT, sid=0)]
    _, st_small = _run([CTATrace(wgs=[tr], n_consumers=1)], {0: tm},
                       cfg=cfg_small)
    _, st_big = _run([CTATrace(wgs=[tr], n_consumers=1)], {0: tm})
    assert st_small["cycles"] > st_big["cycles"]


# ---------------------------------------------------------------------------
# memory hierarchy
# ---------------------------------------------------------------------------

def test_xor_hash_spreads_strided_lines():
    """2048-byte strides concentrate on slices under low-bit hash (§5.4)."""
    from collections import Counter
    cfg = H800
    l2_x = build_memory(cfg, EventQueue())[1]
    l2_n = build_memory(h800_variant(xor_hash=False), EventQueue())[1]
    addrs = [i * 2048 for i in range(4096)]
    cx = Counter(l2_x.slice_of(a) for a in addrs)
    cn = Counter(l2_n.slice_of(a) for a in addrs)
    # naive hash: stride 16 lines -> gcd(16,80)=16 -> only 5 slices hit
    assert len(cn) <= 8
    assert len(cx) >= 40
    assert max(cx.values()) < 4 * (len(addrs) / 80)


def test_lrc_merges_sm_pair_duplicates():
    cfg = H800
    evq = EventQueue()
    lrc, l2, dram = build_memory(cfg, evq)
    done = []
    # same line from SMs 0 and 1 (one pair) while in flight -> merged
    lrc.request(0, 4096, 0, lambda: done.append(0))
    lrc.request(0, 4096, 1, lambda: done.append(1))
    # different pair -> separate L2 request
    lrc.request(0, 4096, 2, lambda: done.append(2))
    while evq._h:
        evq.pop_ready(evq.next_cycle())
    assert sorted(done) == [0, 1, 2]
    assert lrc.merged == 1
    assert l2.requests == 2


def test_mshr_full_stalls_and_recovers():
    cfg = h800_variant(l2_mshr_per_slice=2, lrc_enabled=False)
    evq = EventQueue()
    lrc, l2, dram = build_memory(cfg, evq)
    done = []
    # 8 distinct misses into one slice: 2 MSHRs -> 6 stall, all complete
    sl = l2.slices[0]
    for i in range(8):
        sl.access(0, i * 997, False, lambda i=i: done.append(i))
    while evq._h:
        evq.pop_ready(evq.next_cycle())
    assert len(done) == 8
    assert sl.misses == 8


def test_remote_copy_mirror_serves_near_reads():
    cfg = H800
    evq = EventQueue()
    lrc, l2, dram = build_memory(cfg, evq)
    # find a line whose home slice is far from SM 0 (partition 1)
    line = next(a * 128 for a in range(1000)
                if l2.slice_of(a * 128) >= l2.n // 2)
    l2.slices[l2.slice_of(line)]._insert(line)
    lat = []
    def probe(t0):
        l2.access(t0, line, 0, lambda: lat.append(evq.now - t0))
        while evq._h:
            evq.pop_ready(evq.next_cycle())
    for _ in range(12):                   # repeated far reads
        probe(evq.now)
    assert lat[0] == cfg.l2_far_latency
    assert lat[-1] == cfg.l2_near_latency  # mirror took over


def test_dram_bandwidth_bound():
    """Aggregate DRAM service rate matches the configured bandwidth."""
    cfg = H800
    evq = EventQueue()
    _, _, dram = build_memory(cfg, evq)
    n = 80000
    done = [0]
    for i in range(n):
        dram.access(0, i * 128, lambda: done.__setitem__(0, done[0] + 1))
    t_end = 0
    while evq._h:
        t_end = evq.next_cycle()
        evq.pop_ready(t_end)
    assert done[0] == n
    # subtract the fixed-latency tail of the last line
    busy = t_end - cfg.dram_latency
    achieved = n * 128 / (busy / (cfg.freq_ghz * 1e9)) / 1e9
    assert achieved == pytest.approx(cfg.dram_bw_gbps, rel=0.1)


# ---------------------------------------------------------------------------
# occupancy / scheduling
# ---------------------------------------------------------------------------

def test_occupancy_limit_serializes_waves():
    tm = _tmap()
    def cta():
        tr = [Instr(isa.BUBBLES, cycles=1000)]
        return CTATrace(wgs=[tr], n_consumers=1)
    # 4 CTAs, occupancy 2, 1 SM -> 2 waves of 1000 cycles
    eng, st = _run([cta() for _ in range(4)])
    assert 2000 <= st["cycles"] < 2200
    assert eng.retired == 4
