"""Kernel-program IR tests.

1. **Lowering identity** — the registered ``fa3`` spec must reproduce the
   pre-IR hardcoded generator *instruction for instruction* (a frozen copy
   of that generator lives below as the reference), so the golden cycle
   anchors (73614-cycle reference launch, test_engine_equiv GOLD) cannot
   move.
2. **Scenario properties** — each new kernel asserts a paper-consistent
   ordering: cooperative exposes at least as much softmax bubble as
   ping-pong, non-specialized FA2 is at least as slow as FA3 at equal
   tiling, and split-KV decode's simulated traffic matches its analytical
   hooks.
3. **Driver coverage** — every registered kernel runs under the
   ``simulate_fa3`` driver in full and hierarchical fidelity without
   deadlock.
"""
import math

import pytest

from repro.configs.llama3 import AttnWorkload
from repro.core import analytical, isa
from repro.core.engine import CTATrace
from repro.core.isa import Instr
from repro.core.kprog import registry
from repro.core.kprog.costs import softmax_bubble_cycles
from repro.core.machine import H800, h800_variant
from repro.core.simfa import simulate_fa3
from repro.core.tracegen_fa3 import (TM_K, TM_O, TM_Q, TM_V, FA3Tiling,
                                     fa3_kernel_ctas, make_tmaps)


# ---------------------------------------------------------------------------
# frozen pre-IR reference generator (verbatim from the pre-kprog
# tracegen_fa3.py; the IR lowering is held to this, bit for bit)
# ---------------------------------------------------------------------------

def _legacy_fa3_cta_trace(cfg, *, b, h_q, h_kv, q_block, S, D, tiling,
                          causal=False, q_base_row=0):
    t_m, t_n, stages = tiling.t_m, tiling.t_n, tiling.stages
    n_tiles = math.ceil(S / t_n)
    if causal:
        last_row = q_base_row + q_block * t_m + t_m - 1
        n_tiles = min(n_tiles, math.ceil((last_row + 1) / t_n))
    bubbles = softmax_bubble_cycles(cfg, t_m, t_n, D)
    n_qk = D // 16
    n_pv = math.ceil(t_n / 16)

    prod = []
    cons = [[], []]
    prod.append(Instr(isa.TMA_TENSOR, map_id=TM_Q, sid=98,
                      origin=(b, q_block * t_m, h_q * D), tag="Q"))
    for j in range(n_tiles):
        sk = 2 * (j % stages)
        sv = sk + 1
        prod.append(Instr(isa.ACQUIRE_STAGE, sid=sk))
        prod.append(Instr(isa.TMA_TENSOR, map_id=TM_K, sid=sk,
                          origin=(b, j * t_n, h_kv * D), tag=f"K{j}"))
        prod.append(Instr(isa.ACQUIRE_STAGE, sid=sv))
        prod.append(Instr(isa.TMA_TENSOR, map_id=TM_V, sid=sv,
                          origin=(b, j * t_n, h_kv * D), tag=f"V{j}"))

    for c in (0, 1):
        tr = cons[c]
        tr.append(Instr(isa.MB_WAIT, sid=98))
        gid = 0
        for j in range(n_tiles):
            sk = 2 * (j % stages)
            sv = sk + 1
            tr.append(Instr(isa.MB_WAIT, sid=sk))
            if c == 0:
                tr.append(Instr(isa.BAR_ARRIVE, bid=0))
            else:
                tr.append(Instr(isa.BAR_WAIT, bid=0, n=j + 1))
            for _ in range(n_qk):
                tr.append(Instr(isa.WGMMA, gid=gid, m=t_m, n=t_n, k=16,
                                tag=f"QK{j}"))
            tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
            tr.append(Instr(isa.WGMMA_WAIT, gid=gid, n=1))
            tr.append(Instr(isa.RELEASE_STAGE, sid=sk))
            if c == 0:
                tr.append(Instr(isa.BAR_WAIT, bid=1, n=j + 1))
            else:
                tr.append(Instr(isa.BAR_ARRIVE, bid=1))
            tr.append(Instr(isa.BUBBLES, cycles=bubbles))
            tr.append(Instr(isa.MB_WAIT, sid=sv))
            gid += 1
            for _ in range(n_pv):
                tr.append(Instr(isa.WGMMA, gid=gid, m=t_m, n=D, k=16,
                                tag=f"PV{j}"))
            tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
            tr.append(Instr(isa.WGMMA_WAIT, gid=gid, n=0))
            tr.append(Instr(isa.RELEASE_STAGE, sid=sv))
            gid += 1
        tr.append(Instr(isa.TMA_STORE, map_id=TM_O, gid=99,
                        origin=(b, q_block * t_m, h_q * D), tag="O"))
        tr.append(Instr(isa.TMA_COMMIT, gid=99))
        tr.append(Instr(isa.TMA_WAIT, gid=99, n=0))

    return CTATrace(wgs=[prod] + cons, n_consumers=2,
                    name=f"b{b}h{h_q}q{q_block}")


LAUNCHES = {
    "default": dict(B=1, L=256, S=512, H_kv=1, G=2, D=128,
                    tiling=FA3Tiling()),
    "causal": dict(B=1, L=256, S=512, H_kv=1, G=1, D=128, causal=True,
                   tiling=FA3Tiling()),
    "stages3": dict(B=2, L=128, S=384, H_kv=2, G=1, D=64,
                    tiling=FA3Tiling(t_m=64, t_n=96, stages=3)),
}


@pytest.mark.parametrize("name", sorted(LAUNCHES))
def test_fa3_ir_lowering_is_instruction_identical(name):
    """The IR-lowered FA3 ping-pong spec == the frozen pre-IR generator."""
    kw = dict(LAUNCHES[name])
    tiling = kw.pop("tiling")
    causal = kw.pop("causal", False)
    ctas, _ = fa3_kernel_ctas(H800, tiling=tiling, causal=causal, **kw)
    n_q = math.ceil(kw["L"] / tiling.t_m)
    i = 0
    for b in range(kw["B"]):
        for hkv in range(kw["H_kv"]):
            for g in range(kw["G"]):
                for qb in range(n_q):
                    ref = _legacy_fa3_cta_trace(
                        H800, b=b, h_q=hkv * kw["G"] + g, h_kv=hkv,
                        q_block=qb, S=kw["S"], D=kw["D"], tiling=tiling,
                        causal=causal)
                    got = ctas[i]
                    assert got.wgs == ref.wgs, f"CTA {i} instruction drift"
                    assert got.n_consumers == ref.n_consumers
                    assert got.name == ref.name
                    i += 1
    assert i == len(ctas)


def test_fa3_ir_roles_label_warpgroups():
    ctas, _ = fa3_kernel_ctas(H800, B=1, H_kv=1, G=1, L=64, S=256, D=128)
    assert ctas[0].roles == ["producer", "consumer0", "consumer1"]


def test_fa3_tmaps_unchanged():
    tiling = FA3Tiling()
    got = registry.get("fa3").tmaps(
        AttnWorkload(name="t", B=2, L=256, S=512, H_kv=2, G=2, D=128),
        tiling)
    ref = make_tmaps(2, 256, 512, 4, 2, 128, tiling)
    assert got == ref
    assert set(got) == {TM_Q, TM_K, TM_V, TM_O}


def test_max_ctas_zero_builds_zero_ctas():
    """The falsy-zero guard accident (0 meant "unlimited") is fixed."""
    for max_ctas, expect in ((0, 0), (3, 3), (None, 4)):
        ctas, _ = fa3_kernel_ctas(H800, B=1, H_kv=1, G=1, L=256, S=256,
                                  D=128, max_ctas=max_ctas)
        assert len(ctas) == expect, max_ctas


def test_registry_contents():
    assert registry.available() == ["fa2", "fa3", "fa3_cooperative",
                                    "splitkv_decode"]
    spec = registry.get("fa3")
    assert registry.get(spec) is spec
    with pytest.raises(KeyError):
        registry.get("fa7")


def test_reference_launch_golden_anchor():
    """The reference full-fidelity FA3 launch (the BENCH_engine.json
    "full" workload) must stay at exactly 73614 cycles through the IR."""
    w = AttnWorkload(name="full", B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
    res = simulate_fa3(w, H800, fidelity="full")
    assert res.cycles == 73614
    assert not res.deadlocked


# ---------------------------------------------------------------------------
# scenario properties (paper-consistent orderings)
# ---------------------------------------------------------------------------

# compute-bound probe: few SMs so the tensor core / softmax — not launch
# latency — decide the makespan, and a MUFU-starved variant so the bubble
# outweighs the per-tile WGMMA work it could hide behind
CFG_BOUND = h800_variant(num_sms=2, mufu_ops_per_cycle=4)
CFG_FASTSM = h800_variant(num_sms=2, mufu_ops_per_cycle=4096,
                          fp32_ops_per_cycle=65536, fp16_ops_per_cycle=65536)
W_BOUND = AttnWorkload(name="bound", B=1, L=128, S=2048, H_kv=1, G=1, D=128)


def _exposure(kernel):
    """Exposed softmax cycles: makespan minus the same launch on a machine
    whose CUDA-core throughput makes the bubbles ~free."""
    a = simulate_fa3(W_BOUND, CFG_BOUND, fidelity="full", kernel=kernel)
    b = simulate_fa3(W_BOUND, CFG_FASTSM, fidelity="full", kernel=kernel)
    assert not a.deadlocked and not b.deadlocked
    return a.cycles - b.cycles, a.cycles


def test_cooperative_exposes_at_least_pingpong_bubbles():
    exp_pp, cyc_pp = _exposure("fa3")
    exp_co, cyc_co = _exposure("fa3_cooperative")
    assert exp_co >= exp_pp            # no token pass -> more exposure
    assert exp_co > 0                  # and it is real exposure
    assert cyc_co >= cyc_pp            # which costs latency


def test_fa2_at_least_fa3_latency_at_equal_tiling():
    _, cyc_fa3 = _exposure("fa3")
    _, cyc_fa2 = _exposure("fa2")
    assert cyc_fa2 >= cyc_fa3


def test_fa2_doubles_tile_traffic():
    w = AttnWorkload(name="t", B=1, L=128, S=1024, H_kv=1, G=1, D=128)
    r3 = simulate_fa3(w, H800, fidelity="full", kernel="fa3")
    r2 = simulate_fa3(w, H800, fidelity="full", kernel="fa2")
    # per-worker private rings: ~2x the K/V demand traffic toward L2
    assert r2.l2_bytes > 1.6 * r3.l2_bytes
    # and the kernels' analytical hooks see the same ordering
    s3, s2 = registry.get("fa3"), registry.get("fa2")
    assert s2.l2_traffic(w, 64) > 1.6 * s3.l2_traffic(w, 64)


# ---------------------------------------------------------------------------
# split-KV decode
# ---------------------------------------------------------------------------

W_DECODE = AttnWorkload(name="dec", B=2, L=1, S=4096, H_kv=2, G=4, D=128)


def test_decode_traffic_matches_analytical_hooks():
    spec = registry.get("splitkv_decode")
    res = simulate_fa3(W_DECODE, H800, fidelity="full",
                       kernel="splitkv_decode")
    assert not res.deadlocked
    model_dram = spec.dram_real(W_DECODE, 64, H800.num_sms,
                                H800.occupancy_limit)
    model_l2 = spec.l2_traffic(W_DECODE)
    assert res.dram_bytes == pytest.approx(model_dram, rel=0.05)
    assert res.l2_bytes == pytest.approx(model_l2, rel=0.05)
    # analyze() dispatches through the same hooks
    rep = analytical.analyze(W_DECODE, H800, kernel="splitkv_decode")
    assert rep.l2_bytes == model_l2


def test_decode_splits_fill_the_machine():
    spec = registry.get("splitkv_decode")
    tl = spec.default_tiling()
    assert spec.total_ctas(W_DECODE) == \
        W_DECODE.B * W_DECODE.H_kv * (tl.n_split + 1)
    ctas, tmaps = spec.build(H800, W_DECODE)
    names = [c.name for c in ctas]
    assert sum(1 for n in names if n.endswith("red")) == \
        W_DECODE.B * W_DECODE.H_kv
    # split CTAs launch before the reductions that consume their partials
    first_red = next(i for i, n in enumerate(names) if n.endswith("red"))
    assert first_red == W_DECODE.B * W_DECODE.H_kv * tl.n_split
    assert ctas[0].roles == ["producer", "consumer"]
    assert ctas[-1].roles == ["reducer"]


# ---------------------------------------------------------------------------
# driver coverage: every kernel, both fidelities, no deadlock
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["fa3", "fa3_cooperative", "fa2",
                                    "splitkv_decode"])
@pytest.mark.parametrize("fidelity", ["full", "hierarchical"])
def test_all_kernels_run_both_fidelities(kernel, fidelity):
    w = (W_DECODE if kernel == "splitkv_decode" else
         AttnWorkload(name="t", B=1, L=256, S=512, H_kv=1, G=2, D=128))
    res = simulate_fa3(w, H800, fidelity=fidelity, kernel=kernel, n_sub=2)
    assert not res.deadlocked
    assert res.cycles > 0
    assert res.fidelity == fidelity
    assert res.kernel == kernel


# ---------------------------------------------------------------------------
# analytical: shared bubble arithmetic + per-kernel dispatch
# ---------------------------------------------------------------------------

def test_bubble_arithmetic_is_shared_and_exact():
    # paper §5.2 reference point (88+704+88+44+32; the golden cycle
    # anchors are built on this exact value)
    assert softmax_bubble_cycles(H800, 64, 176, 128) == 956


def test_analyze_takes_t_n_from_tiling():
    w = AttnWorkload(name="t", B=1, L=4096, S=4096, H_kv=8, G=4, D=128)
    base = analytical.analyze(w, H800)
    explicit = analytical.analyze(w, H800, t_n=176)
    assert base.t_ramp == explicit.t_ramp           # 176 is the default
    other = analytical.analyze(w, H800, t_n=96)
    assert other.t_ramp < base.t_ramp               # smaller tile, smaller
    assert other.l2_bytes == base.l2_bytes          # ramp only


def test_analyze_kernel_dispatch_defaults_to_fa3_equations():
    w = AttnWorkload(name="t", B=1, L=4096, S=4096, H_kv=8, G=4, D=128)
    rep = analytical.analyze(w, H800, kernel="fa3")
    assert rep.l2_bytes == analytical.l2_traffic(w, 64)
    assert rep.dram_ideal_bytes == analytical.dram_ideal(w)
    rep2 = analytical.analyze(w, H800, kernel="fa2")
    assert rep2.l2_bytes > rep.l2_bytes
