"""repro.analysis tests: DAG construction on a hand-built trace, critical
path vs makespan, stall-bucket accounting, what-if identity/monotonicity,
sweep driver + cache, and the gantt-as-view refactor."""
import pytest

from repro.analysis import critical_path as cp
from repro.analysis import dag as dag_mod
from repro.analysis import events as ev_mod
from repro.analysis import report, whatif
from repro.analysis.sweep import SweepPoint, knob_grid, run_sweep
from repro.configs.llama3 import AttnWorkload
from repro.core import isa
from repro.core.engine import CTATrace, Engine
from repro.core.gantt import filter_sm, from_events, render_text
from repro.core.isa import Instr, TensorMap
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3


def _tmap(map_id=0, rows=4, cols=64, esz=2):
    return TensorMap(map_id, 0, (1, 1 << 16, cols),
                     (1 << 34, cols * esz, esz), (1, rows, cols), esz)


def _run_traced(ctas, tmaps=None, n_sms=1):
    eng = Engine(H800, n_sms=n_sms, mem_scale=1.0, record_gantt=True)
    for tm in (tmaps or {}).values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    assert not eng.deadlocked
    return eng, st


def _hand_cta():
    """Producer loads one tile; consumer waits, matmuls, drains, bubbles."""
    prod = [
        Instr(isa.ACQUIRE_STAGE, sid=0),
        Instr(isa.TMA_TENSOR, map_id=0, sid=0, origin=(0, 0, 0), tag="K"),
    ]
    cons = [
        Instr(isa.MB_WAIT, sid=0),
        Instr(isa.WGMMA, gid=0, m=64, n=64, k=16, tag="QK"),
        Instr(isa.WGMMA_COMMIT, gid=0),
        Instr(isa.WGMMA_WAIT, gid=0, n=0),
        Instr(isa.RELEASE_STAGE, sid=0),
        Instr(isa.BUBBLES, cycles=100),
    ]
    return CTATrace(wgs=[prod, cons], n_consumers=1, name="hand")


def _hand_dag():
    eng, st = _run_traced([_hand_cta()], {0: _tmap()})
    return dag_mod.from_engine(eng), eng, st


# ---------------------------------------------------------------------------
# DAG construction
# ---------------------------------------------------------------------------

def test_dag_hand_trace_edges():
    dag, eng, st = _hand_dag()
    evs = dag.events
    by_op = {}
    for e in evs:
        by_op.setdefault(e.op, []).append(e)

    # every executed instruction + 1 TMA job + 1 TC execution became events
    assert len(by_op[ev_mod.TMA_LOAD_JOB]) == 1
    assert len(by_op[ev_mod.WGMMA_EXEC]) == 1
    assert len(by_op[isa.MB_WAIT]) == 1

    # mbarrier signal -> wait edge, with the DONE release mode
    wait = by_op[isa.MB_WAIT][0]
    tma = by_op[ev_mod.TMA_LOAD_JOB][0]
    assert (tma.eid, dag_mod.DONE) in dag.preds[wait.eid]
    assert wait.t0 >= tma.t_done

    # drain wait -> the tensor-core execution that satisfied it
    drain = by_op[isa.WGMMA_WAIT][0]
    mma = by_op[ev_mod.WGMMA_EXEC][0]
    assert (mma.eid, dag_mod.DONE) in dag.preds[drain.eid]

    # the WGMMA execution hangs off its issuing lane event
    wg_issue = by_op[isa.WGMMA][0]
    assert (wg_issue.eid, dag_mod.END) in dag.preds[mma.eid]

    # program order chains each warpgroup lane
    for label, eids in dag.threads.items():
        for a, b in zip(eids, eids[1:]):
            assert (a, dag_mod.END) in dag.preds[b]
            assert evs[a].t1 <= evs[b].t0

    # event ids are a topological order and no edge was clamped
    assert all(p < e.eid for e in evs for p, _ in dag.preds[e.eid])
    assert dag.negative_slack == 0


def test_dag_makespan_matches_engine():
    dag, eng, st = _hand_dag()
    assert abs(dag.makespan - st["cycles"]) <= 2


# ---------------------------------------------------------------------------
# critical path
# ---------------------------------------------------------------------------

def test_critical_path_length_equals_makespan():
    dag, _, _ = _hand_dag()
    path = cp.critical_path(dag)
    assert cp.path_length(dag, path) == dag.makespan
    # path is causally ordered and ends at the sink
    assert path[-1] == dag.sink()
    assert all(a < b for a, b in zip(path, path[1:]))
    # contributions telescope to the makespan
    summary = cp.path_summary(dag, path)
    assert sum(summary.values()) == dag.makespan


def test_critical_path_fa3():
    w = AttnWorkload(name="cp", B=1, L=128, S=512, H_kv=1, G=2, D=128)
    res = simulate_fa3(w, H800, fidelity="full", record_events=True)
    dag = dag_mod.build(res.trace.events, res.trace.dispatch_parent)
    path = cp.critical_path(dag)
    summary = cp.path_summary(dag, path)
    assert sum(summary.values()) == dag.makespan
    assert abs(dag.makespan - res.cycles) <= 2
    # an FA3 kernel's critical path must traverse real work, not just waits
    assert summary.get("wgmma", 0) + summary.get("tma", 0) > 0


# ---------------------------------------------------------------------------
# stall attribution
# ---------------------------------------------------------------------------

def test_stall_buckets_sum_to_idle():
    w = AttnWorkload(name="stall", B=1, L=128, S=512, H_kv=1, G=2, D=128)
    res = simulate_fa3(w, H800, fidelity="full", record_events=True)
    dag = dag_mod.build(res.trace.events, res.trace.dispatch_parent)
    rep = cp.attribute_stalls(dag)
    assert rep.per_wg
    for label, buckets in rep.per_wg.items():
        assert set(buckets) == set(cp.BUCKETS)
        assert sum(buckets.values()) == rep.meta[label]["idle"], label
        assert all(v >= 0 for v in buckets.values())
    # producers stream K/V through acquire/release: their idle must be
    # dominated by ring-buffer (barrier) waits, and consumers must show
    # tma or wgmma waits somewhere.  Buckets are keyed by the kernel IR's
    # declared role names, not positional WG indices.
    prod = [l for l in rep.per_wg if l.endswith("/producer")]
    assert prod
    assert any(rep.per_wg[l]["barrier-wait"] > 0 for l in prod)
    roles = rep.by_role()
    assert set(roles) == {"producer", "consumer"}
    assert roles["producer"]["barrier-wait"] > 0
    text = report.render_stall_report(rep, top=4)
    assert "tma-wait" in text and "TOTAL" in text


def test_softmax_bubble_exposure_on_mufu_starved_machine():
    """Starve MUFU throughput so softmax can no longer hide behind the
    ping-pong: the transitive (chain) attribution must surface the exposure
    as softmax-bubble idle, while bucket sums stay exact."""
    from repro.core.machine import h800_variant
    cfg = h800_variant(mufu_ops_per_cycle=2)
    w = AttnWorkload(name="sx", B=1, L=128, S=1024, H_kv=1, G=1, D=128)
    res = simulate_fa3(w, cfg, fidelity="full", record_events=True)
    dag = dag_mod.build(res.trace.events, res.trace.dispatch_parent)
    rep = cp.attribute_stalls(dag)
    tot = rep.totals()
    assert tot["softmax-bubble"] > 0
    for label, buckets in rep.per_wg.items():
        assert sum(buckets.values()) == rep.meta[label]["idle"], label


# ---------------------------------------------------------------------------
# what-if replay
# ---------------------------------------------------------------------------

def test_whatif_identity_is_exact():
    w = AttnWorkload(name="id", B=1, L=128, S=512, H_kv=1, G=2, D=128)
    res = simulate_fa3(w, H800, fidelity="full", record_events=True)
    dag = dag_mod.build(res.trace.events, res.trace.dispatch_parent)
    r = whatif.replay(dag)                      # all knobs x1.0
    assert r.makespan == dag.makespan           # exact, not approximate
    assert abs(r.makespan - res.cycles) / res.cycles <= 0.01


def test_whatif_monotonic_and_bounded():
    dag, _, _ = _hand_dag()
    base = whatif.replay(dag).makespan
    faster_mma = whatif.replay(dag, whatif.Knobs(wgmma=4.0)).makespan
    faster_tma = whatif.replay(dag, whatif.Knobs(tma_bw=4.0)).makespan
    slower_tma = whatif.replay(dag, whatif.Knobs(tma_bw=0.25)).makespan
    assert faster_mma <= base
    assert faster_tma <= base
    assert slower_tma >= base
    # speeding every resource 2x can at most halve the scalable part
    allfast = whatif.replay(dag, whatif.Knobs(tma_bw=2, wgmma=2, softmax=2))
    assert base / 2 <= allfast.makespan <= base


def test_whatif_hand_trace_tma_scaling():
    """On the hand trace the TMA transfer is on the critical path: slowing
    it 4x must push the makespan out by ~3x the streaming portion."""
    dag, _, _ = _hand_dag()
    tma = next(e for e in dag.events if e.op == ev_mod.TMA_LOAD_JOB)
    stream = (tma.t1 - tma.t0) - tma.fixed
    assert stream > 0
    slow = whatif.replay(dag, whatif.Knobs(tma_bw=0.25)).makespan
    assert slow == pytest.approx(dag.makespan + 3 * stream, abs=1)


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------

def test_sweep_serial_with_cache(tmp_path):
    w = AttnWorkload(name="sweep", B=1, L=128, S=256, H_kv=1, G=1, D=128)
    points = [SweepPoint(workload=w, machine=H800, fidelity="full")]
    grid = knob_grid(tma_bw=(1.0, 2.0))
    rows = run_sweep(points, grid, processes=1, cache_dir=str(tmp_path))
    assert len(rows) == 2
    base = next(r for r in rows if r["knobs"]["tma_bw"] == 1.0)
    assert base["pred_cycles"] == pytest.approx(base["base_cycles"], rel=0.01)
    assert all(r["speedup"] > 0 for r in rows)
    cached = list(tmp_path.glob("whatif_*.json"))
    assert len(cached) == 1
    # second run must be served from cache (identical rows, no resim)
    rows2 = run_sweep(points, grid, processes=1, cache_dir=str(tmp_path))
    assert rows2 == rows
    text = report.render_whatif_table(rows)
    assert "speedup" in text


# ---------------------------------------------------------------------------
# gantt as a view over events
# ---------------------------------------------------------------------------

def test_gantt_is_view_over_events():
    eng, st = _run_traced([_hand_cta()], {0: _tmap()})
    g = eng.gantt()
    assert g == from_events(eng.tracer.events)
    lanes = {tag.split(":")[0] for tag, _, _ in g}
    assert lanes == {"tma", "mma", "bubble"}
    assert render_text(g)


def test_filter_sm_keeps_only_requested_ctas():
    # the old `A or (mma and A)` precedence accident reduced to plain A;
    # the simplified form must keep that (correct) behavior
    gantt = [("tma:cta0/wg0:K", 0, 10), ("mma:cta1/wg1:QK", 5, 15),
             ("mma:cta2/wg1:QK", 5, 15), ("bubble:cta3/wg2", 0, 3)]
    kept = filter_sm(gantt, cta_ids=(0, 1))
    assert kept == gantt[:2]
