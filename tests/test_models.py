"""Per-architecture smoke tests (reduced configs) + decode-cache equivalence
+ family-specific invariants. Runs on CPU with 1 device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # jax model-zoo smoke: minutes, not tier-1

from repro.configs import registry
from repro.models import api, attention, mamba, rwkv
from repro.train.loss import chunked_cross_entropy

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list(registry.ARCHS)


def _batch(cfg, B=2, S=24):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["embeds"] = 0.1 * jax.random.normal(KEY, (B, cfg.frontend_len, cfg.d_model))
    if cfg.family == "encdec":
        b["frames"] = 0.1 * jax.random.normal(KEY, (B, cfg.frontend_len, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one SGD step on CPU; shapes + no NaNs."""
    cfg = registry.get(arch).reduced()
    params = api.init(cfg, KEY)
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    def loss_fn(p):
        hidden, aux = api.forward_hidden(cfg, p, batch, remat="none")
        assert hidden.shape[0] == B and hidden.shape[2] == cfg.d_model
        loss, _ = chunked_cross_entropy(hidden[:, -S:],
                                        api.unembed_table(cfg, p),
                                        batch["labels"], chunk=16)
        return loss + 0.01 * jnp.asarray(aux, jnp.float32)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2, _ = jax.value_and_grad(loss_fn)(new_params)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    import dataclasses
    cfg = registry.get(arch).reduced()
    if cfg.family == "moe":
        # ample capacity: token dropping differs between batched prefill and
        # one-token decode by design; equivalence holds when nothing drops
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = api.init(cfg, KEY)
    B, S = 2, 13
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch = _batch(cfg, B, S + 1)
    batch["tokens"] = toks
    hidden, _ = api.forward_hidden(cfg, params, batch, remat="none")
    logits_full = api.unembed(cfg, params, hidden[:, -1:])
    pre = dict(batch, tokens=toks[:, :S])
    _, cache = api.prefill(cfg, params, pre, max_seq=S + cfg.frontend_len + 8)
    logits_dec, cache2 = api.decode(cfg, params, cache, toks[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_cell_assignment_covers_40():
    cells = list(registry.cells())
    assert len(cells) == 40
    skipped = [(c.name, s.name) for c, s, ok, _ in cells if not ok]
    # only pure full-attention archs skip, and only long_500k
    assert all(s == "long_500k" for _, s in skipped)
    assert {"zamba2-7b", "rwkv6-7b"}.isdisjoint({c for c, _ in skipped})


def test_mamba_chunked_matches_recurrent():
    """Chunked SSD == step-by-step recurrence (same state, same output)."""
    cfg = registry.get("zamba2-7b").reduced()
    p = mamba.mamba_init(KEY, cfg)
    B, S = 2, 12
    x = 0.5 * jax.random.normal(KEY, (B, S, cfg.d_model))
    y_chunk, st_chunk = mamba.mamba_apply(p, x, cfg)
    st = mamba.mamba_state_init(cfg, B)
    ys = []
    for t in range(S):
        y_t, st = mamba.mamba_apply(p, x[:, t:t + 1], cfg, state=st)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_chunk, np.float32), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(st_chunk["ssm"]),
                               atol=1e-3, rtol=1e-3)


def test_rwkv_chunked_matches_recurrent():
    cfg = registry.get("rwkv6-7b").reduced()
    p = rwkv.rwkv_init(KEY, cfg)
    B, S, d = 2, 9, cfg.d_model
    x = 0.5 * jax.random.normal(KEY, (B, S, d))
    S0 = jnp.zeros((B, d // cfg.rwkv_head_dim, cfg.rwkv_head_dim, cfg.rwkv_head_dim))
    x_prev = jnp.zeros((B, d))
    y_chunk, S_chunk, _ = rwkv.time_mix(p["tmix"], x, cfg, S0=S0, x_prev=x_prev, chunk=4)
    Sr, xp = S0, x_prev
    ys = []
    for t in range(S):
        y_t, Sr, xp = rwkv.time_mix(p["tmix"], x[:, t:t + 1], cfg, S0=Sr, x_prev=xp)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_chunk, np.float32), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(Sr), np.asarray(S_chunk), atol=1e-3, rtol=1e-3)


def test_flash_ref_matches_naive():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    o1 = attention.flash_ref(q, k, v, causal=True, chunk=16)
    o2 = attention.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)


def test_moe_einsum_vs_scatter_equivalence():
    """With ample capacity both dispatch impls route identically."""
    from repro.models import moe as moe_mod
    import dataclasses
    cfg = dataclasses.replace(registry.get("dbrx-132b").reduced(),
                              capacity_factor=4.0)
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y1, _ = moe_mod.moe_apply(p, x, cfg, impl="einsum", group_size=32)
    y2, _ = moe_mod.moe_apply(p, x, cfg, impl="scatter")
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=2e-2, rtol=2e-2)
