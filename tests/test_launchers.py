"""Launcher entrypoints must run end-to-end on a 1-device mesh: train with
checkpoint/restart + straggler watchdog, and serve with batched requests."""
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess e2e launchers: minutes, not tier-1

ROOT = Path(__file__).resolve().parent.parent


def _run(args, timeout=600):
    return subprocess.run(
        [sys.executable, "-m"] + args, cwd=ROOT, timeout=timeout,
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"})


def test_train_launcher(tmp_path):
    r = _run(["repro.launch.train", "--arch", "minicpm-2b", "--reduced",
              "--dp", "1", "--tp", "1", "--batch", "4", "--seq", "32",
              "--steps", "6", "--ckpt-every", "3",
              "--ckpt-dir", str(tmp_path / "ck")])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 6 steps" in r.stdout
    # restart resumes from the published checkpoint
    r2 = _run(["repro.launch.train", "--arch", "minicpm-2b", "--reduced",
               "--dp", "1", "--tp", "1", "--batch", "4", "--seq", "32",
               "--steps", "8", "--ckpt-every", "3",
               "--ckpt-dir", str(tmp_path / "ck")])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[restart] resumed from step 6" in r2.stdout


def test_serve_launcher():
    r = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--requests",
              "4", "--slots", "2", "--max-new", "3", "--prompt-len", "8",
              "--max-seq", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 4 requests" in r.stdout
    assert "SimFA-TPU decode prediction" in r.stdout
