"""kprog static verifier + runtime hazard sanitizer tests.

1. **Zero false positives** — all 4 registered kernels verify completely
   clean (no errors, no warnings) on their probe workloads, and
   ``registry.get`` resolves them without raising.
2. **Mutation corpus** — each seeded mutation class from the issue
   (dropped release, wait-before-signal, ring over-subscription, barrier
   count mismatch, sid collision, orphaned token, reordered acquire) is
   caught statically with a witness.  A hypothesis extension fuzzes the
   same mutator families when hypothesis is installed.
3. **Engine agreement** — on a sampled subset, the static verdict matches
   the engine outcome: pristine CTAs simulate to completion, mutants
   deadlock (and the engine now explains why via ``deadlock_info``).
4. **Sanitizer** — ``Engine(sanitize=True)`` is bit-neutral on clean runs
   and catches an unguarded ring refill dynamically.
"""
import dataclasses

import pytest

from repro.core import isa
from repro.core.engine import Engine
from repro.core.kprog import registry
from repro.core.kprog.fa2 import FA2NonSpecialized
from repro.core.kprog.fa3 import FA3Tiling
from repro.core.kprog.verify import (BARRIER_UNDERFLOW, DEADLOCK,
                                     RING_OVERSUBSCRIPTION, SID_COLLISION,
                                     UNGUARDED_LOAD, UNSATISFIABLE_WAIT,
                                     WAIT_RELEASE_MISMATCH,
                                     KernelVerificationError, verify_ctas,
                                     verify_spec)
from repro.core.machine import H800

KERNELS = ["fa2", "fa3", "fa3_cooperative", "splitkv_decode"]


def _build(name):
    spec = registry.get(name, verify=False)
    return spec.build(H800, spec.probe_workload())


def _fa3_probe_cta():
    ctas, tmaps = _build("fa3")
    return ctas[0], tmaps


def _clone(trace, **kw):
    return dataclasses.replace(trace, **kw)


def _drop(trace, wg, pred, which=0):
    """Clone ``trace`` with the ``which``-th instruction matching ``pred``
    removed from warpgroup ``wg``."""
    wgs = [list(w) for w in trace.wgs]
    hits = [i for i, ins in enumerate(wgs[wg]) if pred(ins)]
    del wgs[wg][hits[which]]
    return _clone(trace, wgs=[tuple(w) for w in wgs])


# ---------------------------------------------------------------------------
# 1. pristine kernels: zero false positives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", KERNELS)
def test_registered_kernels_verify_clean(kernel):
    spec = registry.get(kernel, verify=False)
    rep = verify_spec(spec)
    assert rep.ok
    assert rep.findings == [], rep.render()   # not even warnings
    assert rep.n_unique >= 1


@pytest.mark.parametrize("kernel", KERNELS)
def test_registry_resolution_verifies_and_caches(kernel):
    spec = registry.get(kernel)               # verify on by default
    assert getattr(spec, "_kprog_verified", False)
    assert spec._kprog_verify_report.ok
    assert registry.get(kernel) is spec       # cached, no re-verification


def test_registry_rejects_illegal_spec():
    class OverPrefetch(FA2NonSpecialized):
        name = "fa2_overprefetch_reject"
        prefetch_depth = 3

    with pytest.raises(KernelVerificationError) as ei:
        registry.get(OverPrefetch())
    assert RING_OVERSUBSCRIPTION in ei.value.report.codes()
    # opt-out resolves the same spec without raising
    spec = OverPrefetch()
    assert registry.get(spec, verify=False) is spec


def test_verify_env_opt_out(monkeypatch):
    class OverPrefetch(FA2NonSpecialized):
        name = "fa2_overprefetch_env"
        prefetch_depth = 3

    monkeypatch.setenv("REPRO_KPROG_VERIFY", "0")
    spec = OverPrefetch()
    assert registry.get(spec) is spec         # env switch skips the check
    monkeypatch.setenv("REPRO_KPROG_VERIFY", "1")
    with pytest.raises(KernelVerificationError):
        registry.get(spec)


def test_verify_ctas_dedups_identical_shapes():
    trace, _ = _fa3_probe_cta()
    rep = verify_ctas([trace, trace, trace], kernel="dup")
    assert rep.n_ctas == 3
    assert rep.n_unique == 1


# ---------------------------------------------------------------------------
# 2. seeded mutation corpus (deterministic)
# ---------------------------------------------------------------------------

def test_dropped_release_is_deadlock_with_witness():
    trace, _ = _fa3_probe_cta()
    ci = trace.roles.index("consumer0")
    bad = _drop(trace, ci, lambda i: i.op == isa.RELEASE_STAGE)
    rep = verify_ctas([bad], kernel="fa3-droprel")
    assert not rep.ok
    assert DEADLOCK in rep.codes()
    assert WAIT_RELEASE_MISMATCH in rep.codes()
    dl = next(f for f in rep.errors if f.code == DEADLOCK)
    assert dl.witness                                   # the wait cycle
    assert any("producer" in hop for hop in dl.witness)
    assert any("consumer0" in hop for hop in dl.witness)


def test_wait_before_signal_is_self_deadlock():
    ctas, _ = _build("splitkv_decode")
    red = next(t for t in ctas if t.name.endswith("red"))
    (stream,) = [list(w) for w in red.wgs]
    waits = [i for i in stream if i.op == isa.MB_WAIT]
    rest = [i for i in stream if i.op != isa.MB_WAIT]
    bad = _clone(red, wgs=(tuple(waits + rest),))
    rep = verify_ctas([bad], kernel="decode-waitfirst")
    assert not rep.ok
    assert DEADLOCK in rep.codes()
    dl = next(f for f in rep.errors if f.code == DEADLOCK)
    assert dl.pc == 0 and dl.op == isa.MB_WAIT


def test_ring_oversubscription_via_prefetch_depth():
    class OverPrefetch(FA2NonSpecialized):
        name = "fa2_overprefetch"
        prefetch_depth = 3                    # ring has only 2 stages

    spec = OverPrefetch()
    rep = verify_spec(spec)
    assert not rep.ok
    assert rep.codes() == {RING_OVERSUBSCRIPTION}
    f = next(f for f in rep.errors if f.witness)
    # the witness names the pre-wrap slots whose sids alias
    assert any("slot" in hop for hop in f.witness)
    assert "alias" in f.detail


def test_ring_oversubscription_via_shrunk_ring():
    """'Shrink a ring': stage count drops to 1 while the prefetch pipeline
    still assumes the old depth."""
    class Shrunk(FA2NonSpecialized):
        name = "fa2_shrunk"
        prefetch_depth = 2                    # the old (legal) depth

        def default_tiling(self):
            return FA3Tiling(stages=1)

    rep = verify_spec(Shrunk())
    assert not rep.ok
    assert RING_OVERSUBSCRIPTION in rep.codes()


def test_barrier_count_mismatch_underflows():
    trace, _ = _fa3_probe_cta()
    ci = trace.roles.index("consumer0")
    wgs = [list(w) for w in trace.wgs]
    bw = max(i for i, ins in enumerate(wgs[ci]) if ins.op == isa.BAR_WAIT)
    wgs[ci][bw] = dataclasses.replace(wgs[ci][bw], n=wgs[ci][bw].n + 99)
    rep = verify_ctas([_clone(trace, wgs=[tuple(w) for w in wgs])],
                      kernel="fa3-barmismatch")
    assert not rep.ok
    assert BARRIER_UNDERFLOW in rep.codes()


def test_sid_collision_ring_vs_token_range():
    trace, _ = _fa3_probe_cta()
    remap = {0: isa.Q_READY_SID}              # ring K stage 0 -> token sid
    wgs = [tuple(dataclasses.replace(i, sid=remap[i.sid])
                 if i.sid in remap else i for i in w) for w in trace.wgs]
    rings = dict(trace.rings)
    rings["K"] = tuple(remap.get(s, s) for s in rings["K"])
    rep = verify_ctas([_clone(trace, wgs=wgs, rings=rings)],
                      kernel="fa3-sidcollision")
    assert not rep.ok
    assert SID_COLLISION in rep.codes()


def test_orphaned_token_is_unsatisfiable():
    trace, _ = _fa3_probe_cta()
    pi = trace.roles.index("producer")
    bad = _drop(trace, pi, lambda i: i.op == isa.TMA_TENSOR
                and i.sid == isa.Q_READY_SID)
    rep = verify_ctas([bad], kernel="fa3-orphantoken")
    assert not rep.ok
    assert UNSATISFIABLE_WAIT in rep.codes()
    f = next(f for f in rep.errors if f.code == UNSATISFIABLE_WAIT)
    assert "q_ready" in f.detail or "98" in f.detail


def test_reordered_acquire_is_unguarded_load():
    trace, _ = _fa3_probe_cta()
    pi = trace.roles.index("producer")
    wgs = [list(w) for w in trace.wgs]
    p = wgs[pi]
    a = next(i for i, ins in enumerate(p) if ins.op == isa.ACQUIRE_STAGE)
    p[a], p[a + 1] = p[a + 1], p[a]           # load now precedes acquire
    rep = verify_ctas([_clone(trace, wgs=[tuple(w) for w in wgs])],
                      kernel="fa3-reorderacq")
    assert not rep.ok
    assert UNGUARDED_LOAD in rep.codes()


# ---------------------------------------------------------------------------
# 3. engine agreement on a sampled subset
# ---------------------------------------------------------------------------

def _engine_run(trace, tmaps):
    eng = Engine(H800, n_sms=1)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch([trace])
    eng.run()
    return eng


def test_pristine_cta_agrees_with_engine():
    trace, tmaps = _fa3_probe_cta()
    assert verify_ctas([trace]).ok
    eng = _engine_run(trace, tmaps)
    assert not eng.deadlocked
    assert eng.deadlock_info is None


def test_dropped_release_agrees_with_engine_deadlock():
    trace, tmaps = _fa3_probe_cta()
    ci = trace.roles.index("consumer0")
    bad = _drop(trace, ci, lambda i: i.op == isa.RELEASE_STAGE)
    assert not verify_ctas([bad]).ok          # static verdict: illegal
    eng = _engine_run(bad, tmaps)
    assert eng.deadlocked                     # dynamic outcome agrees
    info = eng.deadlock_info
    assert info is not None
    assert info["n_blocked"] == 3
    assert info["cycle_witness"]              # satellite: wait-for cycle
    ops = {b["op"] for b in info["blocked"]}
    assert ops == {isa.ACQUIRE_STAGE, isa.MB_WAIT}
    blocked = {b["label"]: b for b in info["blocked"]}
    prod = next(b for k, b in blocked.items() if "producer" in k)
    assert prod["need"] == 2 and prod["have"] == 1
    assert any("consumer" in lbl for lbl in prod["waits_on"])


def test_wait_before_signal_agrees_with_engine_deadlock():
    ctas, tmaps = _build("splitkv_decode")
    red = next(t for t in ctas if t.name.endswith("red"))
    (stream,) = [list(w) for w in red.wgs]
    waits = [i for i in stream if i.op == isa.MB_WAIT]
    rest = [i for i in stream if i.op != isa.MB_WAIT]
    bad = _clone(red, wgs=(tuple(waits + rest),))
    assert not verify_ctas([bad]).ok
    eng = _engine_run(bad, tmaps)
    assert eng.deadlocked
    assert eng.deadlock_info["blocked"][0]["op"] == isa.MB_WAIT


def test_deadlock_info_rides_report():
    from repro.analysis.hazards import render_deadlock
    from repro.obs.report import build_report, render_report
    from repro.core.simfa import SimResult

    trace, tmaps = _fa3_probe_cta()
    ci = trace.roles.index("consumer0")
    bad = _drop(trace, ci, lambda i: i.op == isa.RELEASE_STAGE)
    eng = _engine_run(bad, tmaps)
    st = eng.stats()
    res = SimResult(
        latency_us=st["time_us"], cycles=st["cycles"], fidelity="full",
        n_ctas_total=1, n_ctas_simulated=1, tc_util=st["tc_util"],
        l2_bytes=0.0, l2_delivered_bytes=0.0, dram_bytes=st["dram_bytes"],
        l2_stats=st["l2"], deadlocked=eng.deadlocked,
        deadlock_info=eng.deadlock_info)
    rep = build_report(res, H800)
    assert rep["deadlock"]["cycle_witness"]
    text = render_report(rep)
    assert "** DEADLOCKED **" in text
    assert "circular wait" in text
    assert render_deadlock(eng.deadlock_info)[0].startswith("  deadlock at")


# ---------------------------------------------------------------------------
# 4. runtime sanitizer
# ---------------------------------------------------------------------------

def test_sanitizer_clean_on_pristine_run():
    trace, tmaps = _fa3_probe_cta()
    eng = Engine(H800, n_sms=1, sanitize=True)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch([trace])
    st = eng.run()
    assert eng.sanitizer.issues == []
    # bit-neutrality: identical stats to an unsanitized engine
    assert st == _engine_run(trace, tmaps).stats()


def test_sanitizer_catches_unguarded_refill():
    trace, tmaps = _fa3_probe_cta()
    pi = trace.roles.index("producer")
    # strip the producer's second ACQUIRE of ring K: the tile-1 load then
    # refills sid 2 without arming (stage not yet wrapped -> no WAR yet,
    # but the protocol violation must still be flagged)
    bad = _drop(trace, pi,
                lambda i: i.op == isa.ACQUIRE_STAGE and i.sid == 2)
    eng = Engine(H800, n_sms=1, sanitize=True)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch([bad])
    eng.run()
    assert not eng.deadlocked                 # count semantics still close
    codes = {i.code for i in eng.sanitizer.issues}
    assert "unguarded-load" in codes or "race-war" in codes
    issue = eng.sanitizer.issues[0]
    assert issue.cta == bad.name
    assert "producer" in issue.wg


# ---------------------------------------------------------------------------
# 5. hypothesis extension (runs only when hypothesis is installed; the
# deterministic corpus above always runs, so the mutation classes stay
# covered either way)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                           # container without hypothesis
    HAVE_HYPOTHESIS = False


def _mutators(trace):
    """(name, mutator) pairs; each returns a mutated clone."""
    consumers = [i for i, r in enumerate(trace.roles) if "consumer" in r]

    def drop_release(data):
        wg = data.draw(hst.sampled_from(consumers), label="wg")
        n = sum(1 for i in trace.wgs[wg] if i.op == isa.RELEASE_STAGE)
        which = data.draw(hst.integers(0, n - 1), label="which")
        return _drop(trace, wg, lambda i: i.op == isa.RELEASE_STAGE, which)

    def bump_bar_wait(data):
        wg = data.draw(hst.sampled_from(consumers), label="wg")
        wgs = [list(w) for w in trace.wgs]
        idxs = [i for i, ins in enumerate(wgs[wg]) if ins.op == isa.BAR_WAIT]
        k = data.draw(hst.sampled_from(idxs), label="idx")
        bump = data.draw(hst.integers(50, 500), label="bump")
        wgs[wg][k] = dataclasses.replace(wgs[wg][k], n=wgs[wg][k].n + bump)
        return _clone(trace, wgs=[tuple(w) for w in wgs])

    def remap_sid(data):
        old = data.draw(hst.sampled_from(
            sorted(s for sids in trace.rings.values() for s in sids)),
            label="sid")
        new = data.draw(hst.integers(isa.Q_READY_SID, isa.Q_READY_SID + 4),
                        label="new")
        wgs = [tuple(dataclasses.replace(i, sid=new) if i.sid == old else i
                     for i in w) for w in trace.wgs]
        rings = {r: tuple(new if s == old else s for s in sids)
                 for r, sids in trace.rings.items()}
        return _clone(trace, wgs=wgs, rings=rings)

    def drop_signal(data):
        pi = trace.roles.index("producer")
        n = sum(1 for i in trace.wgs[pi] if i.op == isa.TMA_TENSOR)
        which = data.draw(hst.integers(0, n - 1), label="which")
        return _drop(trace, pi, lambda i: i.op == isa.TMA_TENSOR, which)

    return [("drop_release", drop_release), ("bump_bar_wait", bump_bar_wait),
            ("remap_sid", remap_sid), ("drop_signal", drop_signal)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(data=hst.data())
    def test_fuzzed_mutations_never_verify_silently(data):
        trace, _ = _fa3_probe_cta()
        name, mut = data.draw(hst.sampled_from(_mutators(trace)),
                              label="class")
        rep = verify_ctas([mut(data)], kernel=f"fuzz-{name}")
        # every mutation leaves a trace in the report ...
        assert rep.findings, name
        # ... and whole-class guarantees hold for the hard-error families
        if name in ("bump_bar_wait", "remap_sid", "drop_signal"):
            assert not rep.ok, name
else:
    def test_fuzzed_mutations_never_verify_silently():
        pytest.skip("hypothesis not installed")
