"""SimFA-python analytical model: Eq. (1)-(12) invariants + hypothesis
property tests (paper §3, §6)."""
import math

import pytest
pytest.importorskip("hypothesis")  # optional dev dep: degrade, don't die
from hypothesis import given, settings, strategies as st

from repro.configs.llama3 import AttnWorkload, workload
from repro.core import analytical as A
from repro.core.genz_baseline import genz_dram_traffic
from repro.core.machine import H800, h800_variant


def _w(L=4096, S=None, B=1, H_kv=8, G=4, D=128, causal=False):
    return AttnWorkload(name="t", B=B, L=L, S=S or L, H_kv=H_kv, G=G, D=D,
                        causal=causal)


# ---------------------------------------------------------------------------
# closed forms
# ---------------------------------------------------------------------------

def test_eq1_flops():
    w = _w(L=1024, S=2048, B=2, H_kv=8, G=4, D=128)
    assert A.total_flops(w) == 4 * 2 * 32 * 1024 * 2048 * 128


def test_eq2_l2_traffic_exact():
    w = _w(L=512, S=512, H_kv=2, G=2, D=64)
    t_m = 64
    expect = 2 * 1 * (2 * 2) * 64 * (2 * 512 + math.ceil(512 / 64) * 2 * 512)
    assert A.l2_traffic(w, t_m) == expect


def test_eq3_dram_ideal():
    w = _w(L=1024, S=1024, H_kv=8, G=4, D=128)
    # Q+O (H_kv*G heads) + K+V (H_kv heads), once each
    expect = 2 * 1 * 128 * (2 * 32 * 1024 + 2 * 8 * 1024)
    assert A.dram_ideal(w) == expect


def test_eq4_h800_crossover_between_32k_and_64k():
    """With 25MB effective L2, the ideal regime ends at S* = 25MB/(2*P*D)
    = 51200 — between 48K and 64K, matching paper Fig. 9's transition."""
    for s, ideal in ((16384, True), (32768, True), (49152, True),
                     (65536, False), (131072, False)):
        w = workload("405B", s, batch=1)
        rep = A.analyze(w, H800)
        assert rep.ideal_regime == ideal, s


def test_eq5_wave_count():
    w = _w(L=65536, G=16)
    # G * ceil(L/T_M) / (N_SM * O_limit)
    expect = math.ceil(16 * math.ceil(65536 / 64) / (132 * 2))
    assert A.waves_per_group(w, 64, 132, 2) == expect


def test_eq10_traffic_ratio_approaches_nsm_olimit():
    w = _w(L=262144, G=16)
    rep = A.analyze(w, H800)
    assert not rep.ideal_regime
    ratio = rep.traffic_ratio
    assert ratio == pytest.approx(132 * 2, rel=0.35)


def test_eq12_intensity_approx():
    w = _w(L=65536)
    rep = A.analyze(w, H800, t_m=64)
    assert rep.intensity_approx == 2 * 64 / 2
    assert rep.intensity_l2 == pytest.approx(rep.intensity_approx, rel=0.1)


def test_genz_underestimates_long_sequences():
    w = workload("405B", 131072, batch=1)
    rep = A.analyze(w, H800)
    assert genz_dram_traffic(w) < 0.5 * rep.dram_bytes


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(L=st.integers(256, 1 << 18), G=st.integers(1, 16),
       t_m=st.sampled_from([32, 64, 128]), n_sm=st.integers(16, 264),
       o=st.integers(1, 4))
def test_wave_monotonicity(L, G, t_m, n_sm, o):
    """Waves grow with work (L, G), shrink with concurrency (SMs, occ)."""
    w = _w(L=L, G=G)
    base = A.waves_per_group(w, t_m, n_sm, o)
    assert base >= 1
    assert A.waves_per_group(_w(L=2 * L, G=G), t_m, n_sm, o) >= base
    assert A.waves_per_group(_w(L=L, G=min(16, 2 * G)), t_m, n_sm, o) >= base
    assert A.waves_per_group(w, t_m, 2 * n_sm, o) <= base
    assert A.waves_per_group(w, 2 * t_m, n_sm, o) <= base


@settings(max_examples=60, deadline=None)
@given(L=st.integers(256, 1 << 17), H_kv=st.sampled_from([1, 2, 8]),
       G=st.integers(1, 8), D=st.sampled_from([64, 128]))
def test_traffic_ordering_invariant(L, H_kv, G, D):
    """L2 demand >= realistic DRAM >= ideal DRAM (caches only filter)."""
    w = _w(L=L, H_kv=H_kv, G=G, D=D)
    l2 = A.l2_traffic(w, 64)
    ideal = A.dram_ideal(w)
    real = A.dram_real(w, 64, H800.num_sms, H800.occupancy_limit)
    assert real >= ideal * 0.999
    assert l2 >= real * 0.999


@settings(max_examples=40, deadline=None)
@given(s_log=st.integers(10, 18))
def test_regime_split_continuous_at_boundary(s_log):
    """analyze() never reports MORE traffic in the ideal regime."""
    w = _w(L=1 << s_log)
    rep = A.analyze(w, H800)
    assert rep.dram_bytes >= A.dram_ideal(w) * 0.999
    assert rep.latency > 0
    assert rep.bottleneck in ("compute", "l2", "dram")


@settings(max_examples=30, deadline=None)
@given(l2_mb=st.integers(10, 400))
def test_bigger_l2_never_increases_traffic(l2_mb):
    w = workload("405B", 65536, batch=1)
    small = A.analyze(w, H800)
    big = A.analyze(w, h800_variant(l2_bytes=l2_mb * 1024 * 1024))
    if l2_mb * 1024 * 1024 >= H800.l2_bytes:
        assert big.dram_bytes <= small.dram_bytes * 1.001
