"""Fault injection & watchdog acceptance (docs/robustness.md).

The load-bearing contracts:

  * **identity is bit-exact** — ``Engine(faults=None)``, the explicit
    identity plan, and ``measured_variability(scale=0)`` all reproduce the
    pinned 73614-cycle full-fidelity FA3 anchor, under every scheduler.
    The hooks are read-only when off, so attaching an identity plan draws
    nothing and perturbs nothing.
  * **seeded runs are reproducible** — a perturbed run is a pure function
    of (plan, seed): same seed -> identical stats, different seed ->
    different trajectory.
  * **watchdog salvage** — a budgeted run aborts *at* the budget with a
    usable post-mortem (CTA census, blocked-thread explanation), and an
    untripped watchdog is bit-neutral.
"""
import pytest

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import H800, h800_variant
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas
from repro.faults import (
    CompletionDelay,
    DramJitter,
    FaultPlan,
    Jitter,
    L2Jitter,
    SmOffline,
    SmSlowdown,
    ThrottleWindow,
    TmaJitter,
    Watchdog,
    measured_variability,
)

SCHEDULERS = ("event", "waiter", "broadcast")

# the pinned full-fidelity FA3 reference launch (see test_engine_equiv)
FULL_ANCHOR = {"cycles": 73614, "dram_bytes": 4194304,
               "l2_req_bytes": 31705728, "tma_lines": 565248}
FULL_W = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)

# small/fast launch for perturbation tests
TINY_W = dict(B=1, L=128, S=256, H_kv=1, G=1, D=64)
TINY_TILING = FA3Tiling(t_m=64, t_n=128, stages=2)


def _run_tiny(faults=None, watchdog=None, n_sms=2, scheduler="event"):
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=TINY_TILING, **TINY_W)
    eng = Engine(H800, n_sms=n_sms, mem_scale=n_sms / H800.num_sms,
                 scheduler=scheduler, faults=faults, watchdog=watchdog)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return eng, st


# ---------------------------------------------------------------------------
# identity / bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_identity_plan_bit_exact_on_full_anchor(scheduler):
    """Attaching the identity FaultPlan must not move the pinned anchor by
    a single cycle or byte, under every scheduler — the acceptance bar for
    the read-only-when-off hook discipline."""
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=FA3Tiling(), **FULL_W)
    eng = Engine(H800, scheduler=scheduler, faults=FaultPlan.identity())
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    assert {k: st[k] for k in FULL_ANCHOR} == FULL_ANCHOR
    assert eng.faults.stats()["injected_cycles"] == {
        k: 0 for k in eng.faults.stats()["injected_cycles"]}


def test_scale_zero_variability_is_identity():
    plan = measured_variability(scale=0)
    assert plan.is_identity()
    # and bit-exact against a no-plan run on the tiny launch
    _, st_off = _run_tiny(faults=None)
    _, st_on = _run_tiny(faults=plan)
    assert st_on == st_off


def test_no_plan_and_identity_plan_agree_everywhere():
    for scheduler in SCHEDULERS:
        _, st_off = _run_tiny(faults=None, scheduler=scheduler)
        _, st_on = _run_tiny(faults=FaultPlan.identity(), scheduler=scheduler)
        assert st_on == st_off, scheduler


# ---------------------------------------------------------------------------
# seeded reproducibility
# ---------------------------------------------------------------------------

def test_seeded_runs_reproducible():
    plan = measured_variability(scale=2.0, seed=7)
    eng_a, st_a = _run_tiny(faults=plan)
    eng_b, st_b = _run_tiny(faults=plan)
    assert st_a == st_b
    assert eng_a.faults.stats() == eng_b.faults.stats()
    # a different seed draws a different trajectory
    eng_c, st_c = _run_tiny(faults=plan.with_seed(8))
    assert (st_c["cycles"], eng_c.faults.stats()["injected_cycles"]) != \
           (st_a["cycles"], eng_a.faults.stats()["injected_cycles"])
    # and perturbation only ever adds latency
    _, st_base = _run_tiny(faults=None)
    assert st_a["cycles"] >= st_base["cycles"]
    # traffic is untouched: jitter delays lines, it does not create them
    for k in ("dram_bytes", "l2_req_bytes", "tma_lines"):
        assert st_a[k] == st_base[k]


def test_plan_dict_roundtrip():
    plan = FaultPlan((
        DramJitter(Jitter("lognormal", 40, 0.5)),
        L2Jitter(Jitter("uniform", 10, 4), near=True, far=False),
        TmaJitter(Jitter("constant", 3)),
        CompletionDelay(Jitter("normal", 2, 1)),
        SmSlowdown(factor=1.25, sms=(1,)),
        SmOffline(sms=(0,)),
        ThrottleWindow(t0=100, t1=200, factor=1.5),
    ), seed=42, name="roundtrip")
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    assert not plan.is_identity()


def test_perturbation_kinds_all_inject():
    """Each latency-perturbation category, attached alone, must record
    events in its own bucket (the hooks are actually wired, per site)."""
    cases = {
        "dram": DramJitter(Jitter("constant", 20)),
        "l2": L2Jitter(Jitter("constant", 8)),
        "tma": TmaJitter(Jitter("constant", 4)),
        "completion": CompletionDelay(Jitter("constant", 6)),
        "compute": SmSlowdown(factor=1.5),
    }
    for cat, pert in cases.items():
        eng, st = _run_tiny(faults=FaultPlan((pert,), seed=1))
        stats = eng.faults.stats()
        assert stats["injection_events"][cat] > 0, cat
        assert stats["injected_cycles"][cat] > 0, cat
        assert not eng.deadlocked, cat


def test_sm_offline_completes_on_survivors():
    plan = FaultPlan((SmOffline(sms=(0,)),))
    eng, st = _run_tiny(faults=plan, n_sms=2)
    assert not eng.deadlocked
    assert eng.retired == eng.launched
    for sm in eng.sms:
        if sm.sm_id == 0:
            assert not sm.ctas       # never dispatched to
    _, st_base = _run_tiny(faults=None, n_sms=2)
    assert st["cycles"] >= st_base["cycles"]    # half the chip, never faster
    # offlining the whole chip is a config error, not a hang
    with pytest.raises(ValueError):
        _run_tiny(faults=FaultPlan((SmOffline(sms=(0, 1)),)), n_sms=2)


def test_throttle_window_slows_only_inside_window():
    eng, st = _run_tiny(
        faults=FaultPlan((ThrottleWindow(t0=0, t1=10 ** 9, factor=2.0),)))
    _, st_base = _run_tiny(faults=None)
    assert st["cycles"] > st_base["cycles"]
    # a window entirely after the run is the identity in effect
    eng2, st2 = _run_tiny(
        faults=FaultPlan((ThrottleWindow(t0=10 ** 9, t1=2 * 10 ** 9,
                                         factor=2.0),)))
    assert st2 == st_base
    assert eng2.faults.stats()["injected_cycles"]["compute"] == 0


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_cycle_budget_aborts_at_budget_with_salvage():
    eng, st = _run_tiny(watchdog=Watchdog(max_cycles=2000))
    assert eng.aborted
    assert not eng.deadlocked
    assert st["cycles"] == 2000         # jump clamped: lands AT the budget
    info = eng.abort_info
    assert info["reason"] == "cycle_budget"
    assert info["cycle"] == 2000
    assert info["launched"] > info["retired"]
    assert info["in_flight"] == info["launched"] - info["retired"]
    assert info["census"], "salvage must carry the resident-CTA census"
    assert "blocked" in info            # explain_deadlock-style post-mortem


def test_watchdog_wall_budget_aborts():
    # deadline already expired at the first check -> immediate clean abort
    eng, st = _run_tiny(watchdog=Watchdog(max_wall_s=1e-9, check_every=1))
    assert eng.aborted
    assert eng.abort_info["reason"] == "wall_budget"
    assert st["cycles"] < 2000


def test_untripped_watchdog_is_bit_neutral():
    for scheduler in SCHEDULERS:
        _, st_off = _run_tiny(scheduler=scheduler)
        eng, st_on = _run_tiny(
            watchdog=Watchdog(max_cycles=10 ** 9, max_wall_s=3600),
            scheduler=scheduler)
        assert not eng.aborted
        assert st_on == st_off, scheduler


def test_watchdog_salvages_faulted_run():
    """Budget trip on a perturbed run: the salvage carries the fault stats
    accumulated up to the abort (the sweep-harness consumer)."""
    eng, st = _run_tiny(faults=measured_variability(scale=4.0),
                        watchdog=Watchdog(max_cycles=3000))
    assert eng.aborted
    assert "faults" in eng.abort_info
    inj = eng.abort_info["faults"]["injected_cycles"]
    assert sum(inj.values()) > 0


def test_simulate_forwards_abort_onto_result():
    from repro.core.simfa import simulate_fa3
    w = AttnWorkload(name="wd", B=1, L=128, S=256, H_kv=1, G=1, D=128)
    r = simulate_fa3(w, H800, fidelity="full",
                     faults={"perturbations": [], "seed": 0},
                     watchdog={"max_cycles": 1500})
    assert r.aborted
    assert r.abort_info["reason"] == "cycle_budget"
    assert r.fault_stats is not None
    # and the obs report renders an abort section without blowing up
    from repro.obs.report import build_report, render_report
    rep = build_report(r, H800, workload=w)
    assert rep["abort"]["reason"] == "cycle_budget"
    assert "** ABORTED **" in render_report(rep)


# ---------------------------------------------------------------------------
# sensitivity driver + straggler calibration
# ---------------------------------------------------------------------------

def test_sensitivity_sweep_degradation_curve():
    from repro.faults.sensitivity import degradation_curve, sensitivity_sweep
    w = AttnWorkload(name="sens", B=1, L=128, S=256, H_kv=1, G=1, D=64)
    cfg = h800_variant(num_sms=8)
    rows = sensitivity_sweep(w, cfg, fidelity="full", scales=(0.0, 2.0),
                             seeds=(0, 1), record_stalls=False)
    assert len(rows) == 4
    base = [r for r in rows if r["scale"] == 0.0]
    assert all(r["degradation"] == 1.0 for r in base)
    assert all(not r["aborted"] for r in rows)
    curve = degradation_curve(rows)
    assert [p["scale"] for p in curve] == [0.0, 2.0]
    assert curve[0]["mean"] == 1.0
    assert curve[1]["mean"] >= 1.0
    assert curve[1]["n"] == 2


def test_straggler_policy_from_samples():
    from repro.serve.engine import StragglerPolicy
    pol = StragglerPolicy.from_samples([0.10, 0.11, 0.10, 0.12, 0.30],
                                       percentile=1.0)
    assert pol.expected_step_s == pytest.approx(0.11)
    assert pol.factor == pytest.approx(0.30 / 0.11)
    assert pol.observe(pol.expected_step_s * pol.factor * 1.01)
    assert not pol.observe(pol.expected_step_s)
    assert pol.slow_steps == 1
    # tight distribution: the floor keeps scheduler noise from tripping it
    tight = StragglerPolicy.from_samples([0.1] * 16)
    assert tight.factor == 1.5
    # no samples -> defaults, not a crash
    assert StragglerPolicy.from_samples([]).expected_step_s == 0.1
