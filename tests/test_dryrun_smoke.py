"""Multi-pod dry-run smoke: one (arch x shape) cell must lower+compile on
the production meshes in a fresh subprocess (XLA device-count flags must be
set before jax initializes, so this cannot run in-process)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("arch,shape", [("olmo-1b", "train_4k")])
def test_dryrun_cell_compiles_multi_pod(arch, shape, tmp_path):
    out = tmp_path / "dry.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "multi", "--out", str(out), "--force"],
        cwd=ROOT, timeout=900, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    rec = json.loads(out.read_text())[f"{arch}|{shape}|multi_pod_2x16x16"]
    assert rec["status"] == "ok"
    assert rec["devices"] == 512
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"] > 0
    # fits a 16 GiB v5e chip
    assert rec["mem"]["peak_bytes"] < 16 * 1024**3