"""Deterministic synthetic data pipeline.

Produces a reproducible token stream (splitmix64 over (seed, step, position))
with next-token labels, packed to (B, S); per-family extras (patch/frame
embeddings) come from the same generator. Deterministic by (seed, step) so
restarts resume mid-epoch without a data-state checkpoint, and each DP shard
can generate only its slice at scale.
"""
from __future__ import annotations

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def token_batch(cfg, *, batch: int, seq: int, step: int, seed: int = 0,
                s_tok: int | None = None):
    """Returns the training batch dict for one step (numpy host arrays)."""
    s_tok = s_tok or seq
    idx = (np.uint64(seed) << np.uint64(40)) ^ (np.uint64(step) << np.uint64(20))
    pos = np.arange(batch * (s_tok + 1), dtype=np.uint64) + idx
    with np.errstate(over="ignore"):
        raw = _splitmix64(pos)
    toks = (raw % np.uint64(cfg.vocab_size)).astype(np.int32)
    toks = toks.reshape(batch, s_tok + 1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        n = batch * cfg.frontend_len * cfg.d_model
        with np.errstate(over="ignore"):
            e = _splitmix64(np.arange(n, dtype=np.uint64) + idx)
        out["embeds"] = ((e % np.uint64(2000)).astype(np.float32) / 1000.0 - 1.0
                         ).reshape(batch, cfg.frontend_len, cfg.d_model) * 0.02
    if cfg.family == "encdec":
        n = batch * cfg.frontend_len * cfg.d_model
        with np.errstate(over="ignore"):
            e = _splitmix64(np.arange(n, dtype=np.uint64) + idx + np.uint64(7))
        out["frames"] = ((e % np.uint64(2000)).astype(np.float32) / 1000.0 - 1.0
                         ).reshape(batch, cfg.frontend_len, cfg.d_model) * 0.02
    return out


class DataIterator:
    """Stateless-resumable iterator: state is just (seed, step)."""

    def __init__(self, cfg, batch: int, seq: int, *, seed: int = 0,
                 start_step: int = 0, s_tok: int | None = None):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = start_step
        self.s_tok = s_tok

    def __iter__(self):
        return self

    def __next__(self):
        b = token_batch(self.cfg, batch=self.batch, seq=self.seq,
                        step=self.step, seed=self.seed, s_tok=self.s_tok)
        self.step += 1
        return b

    def state(self):
        return {"seed": self.seed, "step": self.step}
