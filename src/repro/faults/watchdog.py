"""Engine watchdog: wall-clock / sim-cycle budgets with clean salvage.

A runaway configuration (a perturbed plan that starves a consumer, a
what-if machine variant that livelocks the ring, a sweep point that is
simply enormous) used to mean either an un-interruptible multi-hour run
or a killed process with nothing to show.  ``Engine(watchdog=Watchdog(...))``
bounds a run two ways:

  * ``max_cycles`` — a *simulated-time* budget.  The event-driven run
    loop jumps, so the budget is enforced by clamping every time jump to
    the budget cycle and tripping at the loop top — the abort lands *at*
    the budget, not wherever the next event happened to be.
  * ``max_wall_s`` — a *host-time* budget, checked every ``check_every``
    loop iterations via a countdown (one ``perf_counter`` call per batch,
    so the hook costs ~nothing on the hot loop).

On trip the engine aborts cleanly instead of raising: the run loop breaks,
the counter sink's ``finish`` still runs (PM timelines up to the abort are
salvaged), and :func:`salvage` snapshots what a post-mortem needs —
retired / in-flight / pending CTA census per SM, and the same blocked-
thread explanation ``deadlock_info`` carries (``analysis.hazards.
explain_deadlock`` is deliberately reused: it only reads engine state, so
it is as happy to explain "who was waiting at the abort" as a true
deadlock).  The engine exposes ``aborted`` / ``abort_info``; ``simulate``
forwards both onto ``SimResult`` and the obs report renders an "abort"
section.

Like the fault session, the watchdog is read-only over simulated state:
it never wakes, blocks or reorders anything, so a run that finishes under
budget is bit-exact with an unwatched run (asserted in tests/test_faults.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Watchdog:
    """Declarative budget: attach via ``Engine(watchdog=...)`` or
    ``simulate(..., watchdog=...)``.  Either bound may be None."""
    max_wall_s: Optional[float] = None
    max_cycles: Optional[int] = None
    check_every: int = 256          # loop iterations per wall-clock check

    def __post_init__(self):
        if self.max_wall_s is not None and self.max_wall_s <= 0:
            raise ValueError("max_wall_s must be > 0")
        if self.max_cycles is not None and self.max_cycles <= 0:
            raise ValueError("max_cycles must be > 0")
        if self.check_every <= 0:
            raise ValueError("check_every must be > 0")


class WatchdogState:
    """Per-run armed state (the watchdog analogue of FaultSession)."""

    __slots__ = ("plan", "max_cycles", "deadline", "check_every",
                 "_countdown", "reason", "t0")

    def __init__(self, plan: Watchdog):
        self.plan = plan
        self.max_cycles = plan.max_cycles
        self.t0 = time.perf_counter()
        self.deadline = (self.t0 + plan.max_wall_s
                         if plan.max_wall_s is not None else None)
        self.check_every = plan.check_every
        self._countdown = plan.check_every
        self.reason = ""

    def tripped(self, cycle: int) -> bool:
        if self.max_cycles is not None and cycle >= self.max_cycles:
            self.reason = "cycle_budget"
            return True
        if self.deadline is not None:
            self._countdown -= 1
            if self._countdown <= 0:
                self._countdown = self.check_every
                if time.perf_counter() >= self.deadline:
                    self.reason = "wall_budget"
                    return True
        return False

    def clamp(self, cycle: int) -> int:
        """Clamp a time jump so the abort lands at the cycle budget."""
        mc = self.max_cycles
        if mc is not None and cycle > mc:
            return mc
        return cycle

    def wall_s(self) -> float:
        return time.perf_counter() - self.t0


def make_watchdog(plan) -> Optional[WatchdogState]:
    """``Engine.__init__`` entry: None / dict / Watchdog -> armed state."""
    if plan is None:
        return None
    if isinstance(plan, dict):
        plan = Watchdog(**plan)
    if not isinstance(plan, Watchdog):
        raise TypeError(f"watchdog= expects Watchdog | dict | None, "
                        f"got {type(plan).__name__}")
    return WatchdogState(plan)


def salvage(engine, reason: str, wall_s: float) -> Dict:
    """Partial-result snapshot at abort time (``engine.abort_info``).

    Read-only over engine state; runs after the loop has already decided
    to break, so it cannot perturb anything."""
    census = []
    for sm in engine.sms:
        if not sm.ctas:
            continue
        census.append({
            "sm": sm.sm_id,
            "resident_ctas": [cta.idx for cta in sm.ctas],
            "threads": [
                {"label": th.label, "pc": th.pc, "len": th.trace_len,
                 "state": ("done" if th.done() else
                           "stalled" if th.state == 1 else "ready")}
                for cta in sm.ctas for th in cta.threads
            ],
        })
    from repro.analysis.hazards import explain_deadlock
    blocked = explain_deadlock(engine)
    info = {
        "reason": reason,
        "cycle": engine.cycle,
        "wall_s": round(wall_s, 3),
        "launched": engine.launched,
        "retired": engine.retired,
        "in_flight": engine.launched - engine.retired,
        "pending": len(engine.pending),
        "census": census,
        "blocked": blocked,
    }
    if engine.faults is not None:
        info["faults"] = engine.faults.stats()
    return info
