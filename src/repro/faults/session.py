"""Runtime fault session: a :class:`FaultPlan` compiled into the cheap
per-event hooks the engine and memory hierarchy consult.

Hook discipline (the PR-7 counter-sink / PR-8 sanitizer contract): every
hook site in ``core/engine.py`` / ``core/memory.py`` costs a single
``is not None`` test when no session is attached, and a session compiled
from an identity plan returns +0 extra cycles / x1.0 compute scale from
every hook — so attaching it is bit-exact by construction.  All sampling
goes through one private ``random.Random(plan.seed)``; the engine's own
RNG (the RemoteCopy draw stream in ``L2Cache.rng``) is never touched, so
perturbed runs stay reproducible from ``(plan, seed)`` and unperturbed
state stays byte-identical.

The session also keeps *injection stats* — how many extra cycles each
perturbation class added, per category — which the obs layer surfaces
(``CounterSink`` fault series, report "faults" section, manifest stamp).
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from repro.faults.plan import (
    CompletionDelay,
    DramJitter,
    FaultPlan,
    Jitter,
    L2Jitter,
    SmOffline,
    SmSlowdown,
    ThrottleWindow,
    TmaJitter,
)


class FaultSession:
    """Compiled runtime form of a :class:`FaultPlan`.

    Built by ``Engine.__init__`` (one session per engine run — sessions
    hold RNG state and injection counters, so they are never shared);
    consulted from the DRAM/L2/LRC push sites, the TMA submit/finish
    paths, the tensor-core pump and the BUBBLES executor."""

    __slots__ = ("plan", "rng", "_dram", "_l2_near", "_l2_far", "_tma",
                 "_completion", "_slow_all", "_slow_by_sm", "_throttles",
                 "offline", "injected", "events")

    def __init__(self, plan: FaultPlan, n_sms: int):
        self.plan = plan
        self.rng = random.Random(plan.seed)
        self._dram: List[Jitter] = []
        self._l2_near: List[Jitter] = []
        self._l2_far: List[Jitter] = []
        self._tma: List[Jitter] = []
        self._completion: List[Jitter] = []
        self._slow_all = 1.0                    # chip-wide static factor
        self._slow_by_sm: Dict[int, float] = {}
        self._throttles: List[Tuple[int, int, float]] = []
        offline = set()
        for p in plan.perturbations:
            if isinstance(p, DramJitter):
                self._dram.append(p.jitter)
            elif isinstance(p, L2Jitter):
                if p.near:
                    self._l2_near.append(p.jitter)
                if p.far:
                    self._l2_far.append(p.jitter)
            elif isinstance(p, TmaJitter):
                self._tma.append(p.jitter)
            elif isinstance(p, CompletionDelay):
                self._completion.append(p.jitter)
            elif isinstance(p, SmSlowdown):
                if p.sms:
                    for s in p.sms:
                        self._slow_by_sm[s] = \
                            self._slow_by_sm.get(s, 1.0) * p.factor
                else:
                    self._slow_all *= p.factor
            elif isinstance(p, SmOffline):
                offline.update(p.sms)
            elif isinstance(p, ThrottleWindow):
                if p.factor > 1.0 and p.t1 > p.t0:
                    self._throttles.append((p.t0, p.t1, p.factor))
        self.offline = frozenset(s for s in offline if 0 <= s < n_sms)
        if n_sms and len(self.offline) >= n_sms:
            raise ValueError(
                f"FaultPlan {plan.name!r} offlines all {n_sms} SMs — "
                "nothing could ever be dispatched")
        # extra cycles injected, per category (obs surfaces these)
        self.injected: Dict[str, int] = {
            "dram": 0, "l2": 0, "tma": 0, "completion": 0, "compute": 0}
        self.events: Dict[str, int] = {
            "dram": 0, "l2": 0, "tma": 0, "completion": 0, "compute": 0}

    # -- latency hooks (return extra cycles, >= 0) -------------------------
    def _draw(self, jits: List[Jitter], cat: str) -> int:
        extra = 0
        rng = self.rng
        for j in jits:
            extra += j.sample(rng)
        if extra:
            self.injected[cat] += extra
            self.events[cat] += 1
        return extra

    def dram_extra(self) -> int:
        """Extra latency for one DRAM channel access."""
        if not self._dram:
            return 0
        return self._draw(self._dram, "dram")

    def l2_extra(self, far: bool) -> int:
        """Extra latency for one L2 access (hit or miss lookup)."""
        jits = self._l2_far if far else self._l2_near
        if not jits:
            return 0
        return self._draw(jits, "l2")

    def tma_extra(self) -> int:
        """Extra descriptor/launch setup for one submitted TMA job."""
        if not self._tma:
            return 0
        return self._draw(self._tma, "tma")

    def finish_delay(self) -> int:
        """Delay between a TMA job's last line landing and its completion
        (mbarrier signal / store-group retirement) becoming visible."""
        if not self._completion:
            return 0
        return self._draw(self._completion, "completion")

    # -- compute hooks -----------------------------------------------------
    def compute_scale(self, cycle: int, sm_id: int) -> float:
        """Static x throttle-window compute stretch factor (>= 1.0)."""
        f = self._slow_all
        by_sm = self._slow_by_sm
        if by_sm:
            f *= by_sm.get(sm_id, 1.0)
        for t0, t1, tf in self._throttles:
            if t0 <= cycle < t1:
                f *= tf
        return f

    def stretch(self, cycle: int, sm_id: int, dur: int) -> int:
        """Apply the compute stretch to a duration; exact no-op at x1.0."""
        f = self.compute_scale(cycle, sm_id)
        if f == 1.0:
            return dur
        out = max(1, int(round(dur * f)))
        if out > dur:
            self.injected["compute"] += out - dur
            self.events["compute"] += 1
        return out

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "plan": self.plan.describe(),
            "injected_cycles": dict(self.injected),
            "injection_events": dict(self.events),
            "offline_sms": sorted(self.offline),
        }


def make_session(plan: Optional[FaultPlan], n_sms: int
                 ) -> Optional[FaultSession]:
    """``Engine.__init__`` entry: None / dict / FaultPlan -> session.

    Accepting the ``to_dict`` form lets plans cross process boundaries
    (sweep workers) and config files without an import dance."""
    if plan is None:
        return None
    if isinstance(plan, dict):
        plan = FaultPlan.from_dict(plan)
    if not isinstance(plan, FaultPlan):
        raise TypeError(f"faults= expects FaultPlan | dict | None, "
                        f"got {type(plan).__name__}")
    return FaultSession(plan, n_sms)
