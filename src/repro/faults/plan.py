"""Declarative fault / variability plans.

The paper validates the engine at 5.7% MAPE under *ideal, locked-frequency*
conditions; real Hopper parts show measured latency/bandwidth spreads (the
two microbenchmarking studies in PAPERS.md — arxiv 2501.12084, 2402.13499 —
report wide L2 near/far and DRAM latency distributions, and thermally/
power-capped frequency excursions).  A :class:`FaultPlan` describes such a
variability scenario declaratively: a composition of :class:`Perturbation`
values plus a seed, JSON-round-trippable (``to_dict``/``from_dict``) so
plans can live in configs, sweep grids and manifests.

The plan itself is inert data.  It is compiled into runtime hooks by
:class:`repro.faults.session.FaultSession` when attached via
``Engine(faults=plan)``; the contract (enforced in ``tests/test_faults.py``)
is:

  * **off is free** — ``Engine(faults=None)`` costs one ``is None`` test
    per hook site and is bit-exact with pre-faults engines;
  * **identity is exact** — an empty plan, or one whose perturbations all
    have zero magnitude, reproduces every stat and event bit-for-bit
    (perturbation draws only ever *add* cycles, and the fault RNG is
    private — the engine's own RNG stream is never touched);
  * **seeded is reproducible** — the same ``(plan, seed)`` yields the same
    stats/events on every run; a different seed yields a different (but
    equally reproducible) sample path.

Perturbation catalogue (docs/robustness.md has the worked examples):

  =================  ====================================================
  :class:`DramJitter`       extra latency per DRAM channel access
  :class:`L2Jitter`         extra latency per L2 hit/miss (near/far gated)
  :class:`TmaJitter`        extra descriptor/launch setup per TMA job
  :class:`CompletionDelay`  delayed delivery of async TMA completions
                            (mbarrier signal / store-group retirement)
  :class:`SmSlowdown`       per-SM compute stretch (bubbles + tensor core)
  :class:`SmOffline`        SMs removed from CTA dispatch entirely
  :class:`ThrottleWindow`   time-windowed global compute stretch
                            (thermal / power capping event)
  =================  ====================================================
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Any, Dict, Tuple

DISTRIBUTIONS = ("constant", "uniform", "normal", "lognormal")


@dataclass(frozen=True)
class Jitter:
    """A non-negative integer-cycle latency distribution.

    ``cycles`` is the location parameter (the constant value / uniform
    midpoint / normal mean / lognormal median); ``spread`` the scale
    (uniform half-width / normal std / lognormal sigma).  Samples are
    clamped at zero — a perturbation can only ever *add* latency, which is
    what makes zero-magnitude jitters exactly identity."""
    dist: str = "constant"
    cycles: float = 0.0
    spread: float = 0.0

    def __post_init__(self):
        if self.dist not in DISTRIBUTIONS:
            raise ValueError(f"unknown jitter dist {self.dist!r}; "
                             f"expected one of {DISTRIBUTIONS}")
        if self.cycles < 0 or self.spread < 0:
            raise ValueError("jitter cycles/spread must be >= 0")

    def is_zero(self) -> bool:
        return self.cycles == 0 and self.spread == 0

    def sample(self, rng) -> int:
        """One draw, in whole cycles, >= 0.  ``rng`` is the fault session's
        private ``random.Random``."""
        if self.is_zero():
            return 0
        d = self.dist
        if d == "constant":
            x = self.cycles
        elif d == "uniform":
            x = rng.uniform(self.cycles - self.spread,
                            self.cycles + self.spread)
        elif d == "normal":
            x = rng.gauss(self.cycles, self.spread)
        else:  # lognormal: median = cycles, sigma = spread
            x = (self.cycles or 1.0) * math.exp(rng.gauss(0.0, self.spread))
        return max(0, int(round(x)))


@dataclass(frozen=True)
class Perturbation:
    """Base marker; concrete perturbations carry a class-level ``kind``."""
    kind = "perturbation"

    def is_identity(self) -> bool:
        return False


@dataclass(frozen=True)
class DramJitter(Perturbation):
    """Extra latency per DRAM access (models the measured DRAM latency
    spread; applied on top of ``GPUMachine.dram_latency``)."""
    kind = "dram_jitter"
    jitter: Jitter = field(default_factory=Jitter)

    def is_identity(self) -> bool:
        return self.jitter.is_zero()


@dataclass(frozen=True)
class L2Jitter(Perturbation):
    """Extra latency per L2 access.  ``near``/``far`` gate which partition
    accesses draw (the microbenchmarked near/far spreads differ)."""
    kind = "l2_jitter"
    jitter: Jitter = field(default_factory=Jitter)
    near: bool = True
    far: bool = True

    def is_identity(self) -> bool:
        return self.jitter.is_zero() or not (self.near or self.far)


@dataclass(frozen=True)
class TmaJitter(Perturbation):
    """Extra descriptor/launch setup latency per submitted TMA job."""
    kind = "tma_jitter"
    jitter: Jitter = field(default_factory=Jitter)

    def is_identity(self) -> bool:
        return self.jitter.is_zero()


@dataclass(frozen=True)
class CompletionDelay(Perturbation):
    """Delayed delivery of an async TMA job completion: the cycles between
    the last line landing and the mbarrier signal / store-group retirement
    becoming visible to waiters."""
    kind = "completion_delay"
    jitter: Jitter = field(default_factory=Jitter)

    def is_identity(self) -> bool:
        return self.jitter.is_zero()


@dataclass(frozen=True)
class SmSlowdown(Perturbation):
    """Stretch compute durations (BUBBLES + tensor-core ops) on the listed
    SMs by ``factor`` (>= 1).  Empty ``sms`` means every SM — a chip-wide
    frequency derate."""
    kind = "sm_slowdown"
    factor: float = 1.0
    sms: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.factor < 1.0:
            raise ValueError("SmSlowdown factor must be >= 1")

    def is_identity(self) -> bool:
        return self.factor == 1.0


@dataclass(frozen=True)
class SmOffline(Perturbation):
    """Remove SMs from CTA dispatch entirely (a dead/fenced SM)."""
    kind = "sm_offline"
    sms: Tuple[int, ...] = ()

    def is_identity(self) -> bool:
        return not self.sms


@dataclass(frozen=True)
class ThrottleWindow(Perturbation):
    """Global compute stretch by ``factor`` while ``t0 <= cycle < t1`` —
    a thermal or power-capping event."""
    kind = "throttle"
    t0: int = 0
    t1: int = 0
    factor: float = 1.0

    def __post_init__(self):
        if self.factor < 1.0:
            raise ValueError("ThrottleWindow factor must be >= 1")
        if self.t1 < self.t0:
            raise ValueError("ThrottleWindow needs t0 <= t1")

    def is_identity(self) -> bool:
        return self.factor == 1.0 or self.t1 <= self.t0


PERTURBATION_TYPES = {
    cls.kind: cls for cls in (DramJitter, L2Jitter, TmaJitter,
                              CompletionDelay, SmSlowdown, SmOffline,
                              ThrottleWindow)
}


@dataclass(frozen=True)
class FaultPlan:
    """A named, seeded composition of perturbations.

    ``FaultPlan(())`` / :meth:`identity` is the do-nothing plan — attaching
    it must be bit-exact (the acceptance bar).  ``seed`` drives the fault
    session's private RNG; :meth:`with_seed` derives sibling sample paths
    for Monte-Carlo use (``faults.sensitivity.step_time_samples``)."""
    perturbations: Tuple[Perturbation, ...] = ()
    seed: int = 0
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "perturbations", tuple(self.perturbations))

    @staticmethod
    def identity(name: str = "identity") -> "FaultPlan":
        return FaultPlan((), name=name)

    def is_identity(self) -> bool:
        return all(p.is_identity() for p in self.perturbations)

    def with_seed(self, seed: int) -> "FaultPlan":
        return replace(self, seed=seed)

    # -- declarative round-trip --------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "seed": self.seed,
            "perturbations": [{"kind": p.kind, **asdict(p)}
                              for p in self.perturbations],
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "FaultPlan":
        perts = []
        for pd in d.get("perturbations", ()):
            pd = dict(pd)
            kind = pd.pop("kind")
            cls = PERTURBATION_TYPES.get(kind)
            if cls is None:
                raise ValueError(f"unknown perturbation kind {kind!r}")
            for f in fields(cls):
                if f.name in pd and isinstance(pd[f.name], dict):
                    pd[f.name] = Jitter(**pd[f.name])
                elif f.name in pd and isinstance(pd[f.name], list):
                    pd[f.name] = tuple(pd[f.name])
            perts.append(cls(**pd))
        return FaultPlan(tuple(perts), seed=d.get("seed", 0),
                         name=d.get("name", ""))

    def describe(self) -> Dict[str, Any]:
        """Compact summary for manifests / reports."""
        return {
            "name": self.name or None,
            "seed": self.seed,
            "n_perturbations": len(self.perturbations),
            "kinds": sorted({p.kind for p in self.perturbations}),
            "identity": self.is_identity(),
        }
