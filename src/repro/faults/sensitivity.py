"""Perturbation-magnitude sensitivity sweeps (the robustness analogue of
``analysis.whatif``).

``analysis.whatif`` asks "what if the machine were *better* along one
axis"; this module asks "how fast does the prediction degrade as measured
variability grows".  The sweep axis is the ``scale`` knob of
:func:`repro.faults.measured_variability` — 0 is the paper's ideal
locked-frequency model (and bit-exact with no plan at all), 1 is the
microbenchmarked Hopper spread, >1 is stress — optionally crossed with
seeds for Monte-Carlo spread at each magnitude.

Two consumers:

  * ``benchmarks/bench_faults.py`` — per-kernel latency + stall-attribution
    degradation curves, written as a JSON artifact and smoke-checked in CI;
  * ``serve.engine.StragglerPolicy.from_samples`` — via
    :func:`step_time_samples`, which Monte-Carlos one workload's step time
    under a plan so the serving deadline comes from the modeled tail
    instead of a hand-picked factor.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.faults import FaultPlan, measured_variability

DEFAULT_SCALES = (0.0, 0.5, 1.0, 2.0, 4.0)


def _stall_buckets(result) -> Optional[Dict[str, float]]:
    trace = getattr(result, "trace", None)
    if trace is None or not trace.events:
        return None
    from repro.analysis import dag as dag_mod
    from repro.analysis.critical_path import attribute_stalls
    sr = attribute_stalls(dag_mod.build(trace.events, trace.dispatch_parent))
    return {k: round(v, 1) for k, v in sr.totals().items()}


def sensitivity_sweep(workload, cfg, *,
                      kernel: str = "fa3",
                      fidelity: str = "auto",
                      scales: Sequence[float] = DEFAULT_SCALES,
                      seeds: Sequence[int] = (0,),
                      throttle: bool = False,
                      record_stalls: bool = True,
                      watchdog=None) -> List[Dict]:
    """One latency/stall degradation curve: rows for every (scale, seed).

    Each row reports cycles, latency, the degradation ratio vs. the
    scale-0 baseline (same kernel, same fidelity), the per-category
    injected-cycle totals, and — when ``record_stalls`` — the 5-bucket
    stall attribution so the curve shows *where* the lost cycles went
    (e.g. L2 jitter surfacing as consumer mbarrier waits)."""
    from repro.core.simfa import simulate_fa3

    rows: List[Dict] = []
    base_cycles: Optional[float] = None
    for scale in scales:
        for seed in seeds:
            plan = (FaultPlan.identity() if scale == 0
                    else measured_variability(scale=scale, seed=seed,
                                              throttle=throttle))
            r = simulate_fa3(workload, cfg, kernel=kernel, fidelity=fidelity,
                             record_events=record_stalls, faults=plan,
                             watchdog=watchdog)
            if base_cycles is None:
                base_cycles = r.cycles
            row = {
                "workload": workload.name,
                "kernel": r.kernel,
                "fidelity": r.fidelity,
                "scale": scale,
                "seed": seed,
                "plan": plan.name,
                "cycles": r.cycles,
                "latency_us": r.latency_us,
                "degradation": r.cycles / max(base_cycles, 1e-9),
                "aborted": r.aborted,
                "injected_cycles": (r.fault_stats or {}).get(
                    "injected_cycles"),
            }
            if record_stalls:
                row["stall_buckets"] = _stall_buckets(r)
            rows.append(row)
    return rows


def degradation_curve(rows: Sequence[Dict]) -> List[Dict]:
    """Collapse Monte-Carlo rows to one point per scale: mean / min / max
    degradation (the published curve shape)."""
    by_scale: Dict[float, List[float]] = {}
    for r in rows:
        by_scale.setdefault(r["scale"], []).append(r["degradation"])
    return [{"scale": s,
             "mean": sum(v) / len(v),
             "min": min(v),
             "max": max(v),
             "n": len(v)}
            for s, v in sorted(by_scale.items())]


def step_time_samples(workload, cfg, *,
                      kernel: str = "fa3",
                      fidelity: str = "auto",
                      scale: float = 1.0,
                      n: int = 16,
                      seed0: int = 0,
                      throttle: bool = False) -> List[float]:
    """Monte-Carlo one workload's step time (seconds) under the measured-
    variability plan at ``scale`` — ``n`` independent seeds, one latency
    sample each.  Feed the list straight to
    ``StragglerPolicy.from_samples`` to calibrate a serving deadline from
    the modeled distribution."""
    from repro.core.simfa import simulate_fa3

    out: List[float] = []
    for i in range(n):
        plan = measured_variability(scale=scale, seed=seed0 + i,
                                    throttle=throttle)
        r = simulate_fa3(workload, cfg, kernel=kernel, fidelity=fidelity,
                         faults=plan)
        out.append(r.latency_us * 1e-6)
    return out
