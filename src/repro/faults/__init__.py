"""Fault injection & variability modeling (docs/robustness.md).

Public surface:

  * :class:`FaultPlan` + the perturbation catalogue — declarative, seeded
    variability scenarios attached via ``Engine(faults=...)`` /
    ``simulate(..., faults=...)``.
  * :class:`Watchdog` — wall-clock / sim-cycle run budgets with clean
    abort + partial-result salvage (``Engine(watchdog=...)``).
  * :func:`measured_variability` — the default plan built from the
    microbenchmarked Hopper envelopes in ``core.machine.H800_VARIABILITY``.
  * :mod:`repro.faults.sensitivity` — perturbation-magnitude sweep driver
    (the robustness analogue of ``analysis.whatif``), also the step-time
    sampler that feeds ``serve.engine.StragglerPolicy``.
"""
from repro.faults.plan import (
    CompletionDelay,
    DramJitter,
    FaultPlan,
    Jitter,
    L2Jitter,
    Perturbation,
    SmOffline,
    SmSlowdown,
    ThrottleWindow,
    TmaJitter,
)
from repro.faults.session import FaultSession, make_session
from repro.faults.watchdog import Watchdog, WatchdogState, make_watchdog

__all__ = [
    "CompletionDelay", "DramJitter", "FaultPlan", "FaultSession", "Jitter",
    "L2Jitter", "Perturbation", "SmOffline", "SmSlowdown", "ThrottleWindow",
    "TmaJitter", "Watchdog", "WatchdogState", "make_session",
    "make_watchdog", "measured_variability",
]


def measured_variability(scale: float = 1.0, seed: int = 0,
                         throttle: bool = False) -> FaultPlan:
    """The measured-Hopper-spread plan: normal latency jitters at the
    ``H800_VARIABILITY`` one-sigma envelopes (times ``scale``), plus —
    when ``throttle=True`` — a chip-wide sustained power-cap derate.

    ``scale=0`` is exactly the identity plan (the bit-exactness anchor in
    tests), which makes it the natural sweep axis for
    ``faults.sensitivity``: 0 -> ideal paper model, 1 -> measured spread,
    >1 -> stress."""
    from repro.core.machine import H800_VARIABILITY as V
    perts = [
        DramJitter(Jitter("normal", 0.0, V["dram_jitter_std"] * scale)),
        L2Jitter(Jitter("normal", 0.0, V["l2_near_jitter_std"] * scale),
                 near=True, far=False),
        L2Jitter(Jitter("normal", 0.0, V["l2_far_jitter_std"] * scale),
                 near=False, far=True),
        TmaJitter(Jitter("normal", 0.0, V["tma_jitter_std"] * scale)),
        CompletionDelay(
            Jitter("normal", 0.0, V["completion_jitter_std"] * scale)),
    ]
    if throttle and scale > 0:
        perts.append(SmSlowdown(
            factor=1.0 + (V["throttle_factor"] - 1.0) * scale))
    return FaultPlan(tuple(perts), seed=seed,
                     name=f"measured_variability(x{scale:g})")
