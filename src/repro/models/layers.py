"""Primitive layers: norms, linears, rotary embeddings, MLP blocks.

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of ``*_init(key, ...) -> params`` and a pure apply function. Compute
follows cfg.compute_dtype (bf16 by default) with fp32 norms/softmax.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    stddev = 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, *, dtype=jnp.bfloat16):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparam_ln":  # OLMo: non-parametric LayerNorm
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = y * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style half rotation)
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim), positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]   # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def mlp_init(key, kind: str, d: int, d_ff: int, *, bias: bool = False):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wg": dense_init(ks[0], d, d_ff, bias=bias),
            "wu": dense_init(ks[1], d, d_ff, bias=bias),
            "wd": dense_init(ks[2], d_ff, d, bias=bias),
        }
    if kind == "gelu_mlp":
        return {
            "wu": dense_init(ks[0], d, d_ff, bias=bias),
            "wd": dense_init(ks[1], d_ff, d, bias=bias),
        }
    raise ValueError(kind)


def apply_mlp(kind: str, p, x, *, dtype=jnp.bfloat16):
    if kind in ("swiglu", "geglu"):
        g = dense(p["wg"], x, dtype=dtype)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * dense(p["wu"], x, dtype=dtype)
        return dense(p["wd"], h, dtype=dtype)
    h = jax.nn.gelu(dense(p["wu"], x, dtype=dtype))
    return dense(p["wd"], h, dtype=dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int):
    return {"table": truncated_normal(key, (vocab, d), 1.0 / math.sqrt(d))}


def embed(p, tokens, *, dtype=jnp.bfloat16):
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)
