"""RWKV6 "Finch" block: data-dependent decay linear RNN (attention-free).

Time-mix uses per-channel decay w_t[i] in (0,1); the chunked-parallel form
keeps every exponent non-positive:

  y_t = r~_t @ S_0 + sum_{s<t} (sum_i r_t[i] k_s[i] e^{lc[t-1,i]-lc[s,i]}) v_s
        + (r_t . (u*k_t)) v_t
  S'  = diag(e^{lc[Q]}) S_0 + sum_s diag(e^{lc[Q]-lc[s]}) k_s v_s^T

where lc is the within-chunk cumulative log decay (lc <= 0, lc[t-1]-lc[s] <= 0
for s <= t-1). The (Q,Q,p) contraction is exact — no log-space clamping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

N_MIX = 5          # w, k, v, r, g DDLerp mixes
LORA_MIX = 32
LORA_DECAY = 64


def rwkv_init(key, cfg):
    d = cfg.d_model
    p = cfg.rwkv_head_dim
    H = d // p
    ks = jax.random.split(key, 12)
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {
        "tmix": {
            "maa_x": z(d), "maa_wkvrg": z(N_MIX, d),
            "maa_w1": layers.truncated_normal(ks[0], (d, N_MIX * LORA_MIX), 0.02),
            "maa_w2": layers.truncated_normal(ks[1], (N_MIX, LORA_MIX, d), 0.02),
            "decay": jnp.full((d,), -4.0, jnp.float32),
            "decay_w1": layers.truncated_normal(ks[2], (d, LORA_DECAY), 0.02),
            "decay_w2": layers.truncated_normal(ks[3], (LORA_DECAY, d), 0.02),
            "u": layers.truncated_normal(ks[4], (H, p), 0.3),
            "wr": layers.dense_init(ks[5], d, d),
            "wk": layers.dense_init(ks[6], d, d),
            "wv": layers.dense_init(ks[7], d, d),
            "wg": layers.dense_init(ks[8], d, d),
            "wo": layers.dense_init(ks[9], d, d),
            "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                     "bias": jnp.zeros((d,), jnp.float32)},
        },
        "cmix": {
            "maa_k": z(d), "maa_r": z(d),
            "wk": layers.dense_init(ks[10], d, cfg.d_ff),
            "wv": layers.dense_init(ks[11], cfg.d_ff, d),
            "wr": layers.dense_init(jax.random.fold_in(key, 99), d, d),
        },
    }


def _token_shift(x, x_prev):
    """x: (B,S,d); x_prev: (B,d) last token of the previous call."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _group_norm(p, x, H):
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y.reshape(B, S, d) * p["scale"] + p["bias"]).astype(x.dtype)


def time_mix(p, x, cfg, *, S0, x_prev, chunk: int = 64):
    """x: (B,S,d). S0: (B,H,p,p) state (k-dim, v-dim). Returns y, S', x_last."""
    B, S, d = x.shape
    ph = cfg.rwkv_head_dim
    H = d // ph
    f32 = jnp.float32
    xf = x.astype(f32)
    sx = _token_shift(xf, x_prev)
    dx = sx - xf
    xxx = xf + dx * p["maa_x"]
    mix = jnp.tanh(xxx @ p["maa_w1"]).reshape(B, S, N_MIX, LORA_MIX)
    mix = jnp.einsum("bsnl,nld->bsnd", mix, p["maa_w2"])          # (B,S,5,d)
    xw, xk, xv, xr, xg = [xf + dx * (p["maa_wkvrg"][i] + mix[:, :, i])
                          for i in range(N_MIX)]

    logw = -jnp.exp(p["decay"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"])
    logw = jnp.clip(logw, -60.0, -1e-5)                            # (B,S,d) < 0
    r = (xr @ p["wr"]["w"].astype(f32)).reshape(B, S, H, ph)
    k = (xk @ p["wk"]["w"].astype(f32)).reshape(B, S, H, ph)
    v = (xv @ p["wv"]["w"].astype(f32)).reshape(B, S, H, ph)
    g = jax.nn.silu(xg @ p["wg"]["w"].astype(f32))
    lw = logw.reshape(B, S, H, ph)
    u = p["u"]

    if S == 1:  # decode: y = r.(S0 + u k v^T); S' = diag(w) S0 + k v^T
        r1, k1, v1, lw1 = r[:, 0], k[:, 0], v[:, 0], lw[:, 0]
        kv = k1[..., :, None] * v1[..., None, :]                   # (B,H,p,p)
        y = jnp.einsum("bhi,bhij->bhj", r1, S0 + u[None, :, :, None] * kv)
        S_new = S0 * jnp.exp(lw1)[..., None] + kv
        y = y.reshape(B, 1, d)
    else:
        Q = min(chunk, S)
        nc = -(-S // Q)
        pad = nc * Q - S
        if pad:
            r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
            lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))   # pad logw=0: w=1
        resh = lambda t: t.reshape(B, nc, Q, H, ph).transpose(1, 0, 3, 2, 4)
        rc, kc, vc, lc_ = map(resh, (r, k, v, lw))                 # (nc,B,H,Q,p)

        tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)               # strict lower

        def body(S0_, inp):
            rq, kq, vq, la = inp                                   # (B,H,Q,p)
            lc = jnp.cumsum(la, axis=2)                            # (B,H,Q,p)
            lprev = jnp.concatenate(
                [jnp.zeros_like(lc[:, :, :1]), lc[:, :, :-1]], axis=2)  # lc[t-1]
            # A[t,s] = sum_i r[t,i] k[s,i] exp(lprev[t,i]-lc[s,i]), s < t
            rel = lprev[:, :, :, None, :] - lc[:, :, None, :, :]   # (B,H,Q,Q,p)
            E = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
            A = jnp.einsum("bhti,bhtsi,bhsi->bhts", rq, E, kq)
            diag = jnp.einsum("bhti,hi,bhti->bht", rq, u, kq)
            y = jnp.einsum("bhts,bhsj->bhtj", A, vq) + diag[..., None] * vq
            y = y + jnp.einsum("bhti,bhij->bhtj", rq * jnp.exp(lprev), S0_)
            # state: S' = diag(e^{lc[Q]}) S0 + sum_s diag(e^{lc[Q]-lc[s]}) k_s v_s
            k_hat = kq * jnp.exp(lc[:, :, -1:, :] - lc)
            S_new_ = S0_ * jnp.exp(lc[:, :, -1])[..., None] + jnp.einsum(
                "bhsi,bhsj->bhij", k_hat, vq)
            return S_new_, y

        S_new, ys = jax.lax.scan(body, S0.astype(f32), (rc, kc, vc, lc_))
        y = ys.transpose(1, 0, 3, 2, 4).reshape(B, nc * Q, d)[:, :S]

    y = _group_norm(p["ln_x"], y, H)
    y = (y.astype(f32) * g) @ p["wo"]["w"].astype(f32)
    return y.astype(x.dtype), S_new, xf[:, -1]


def channel_mix(p, x, *, x_prev):
    f32 = jnp.float32
    xf = x.astype(f32)
    sx = _token_shift(xf, x_prev)
    dx = sx - xf
    xk = xf + dx * p["maa_k"]
    xr = xf + dx * p["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]["w"].astype(f32)))
    kv = k @ p["wv"]["w"].astype(f32)
    y = jax.nn.sigmoid(xr @ p["wr"]["w"].astype(f32)) * kv
    return y.astype(x.dtype), xf[:, -1]


def rwkv_state_init(cfg, batch):
    d = cfg.d_model
    p = cfg.rwkv_head_dim
    H = d // p
    return {
        "S": jnp.zeros((batch, H, p, p), jnp.float32),
        "x_att": jnp.zeros((batch, d), jnp.float32),
        "x_cmix": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv_block(params, x, cfg, norms, *, state):
    """One RWKV layer: ln1 -> time_mix -> ln2 -> channel_mix (pre-norm)."""
    h, S_new, x_att = time_mix(
        params["tmix"], layers.apply_norm("layernorm", norms["ln1"], x), cfg,
        S0=state["S"], x_prev=state["x_att"])
    x = x + h
    h, x_cm = channel_mix(
        params["cmix"], layers.apply_norm("layernorm", norms["ln2"], x),
        x_prev=state["x_cmix"])
    x = x + h
    return x, {"S": S_new, "x_att": x_att, "x_cmix": x_cm}
