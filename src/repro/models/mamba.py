"""Mamba2 (SSD) block: chunked state-space dual form.

Per-head scalar decay a_t = exp(dt_t * A_head) makes the intra-chunk term a
plain masked (Q x Q) matrix — MXU friendly. Inter-chunk state is carried by a
lax.scan over chunks, all decay exponents are non-positive (stable).

Decode keeps (conv_state, ssm_state) and performs the O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    p = 64                               # mamba2 head dim
    n_heads = d_inner // p
    return d_inner, p, n_heads


def mamba_init(key, cfg):
    d = cfg.d_model
    N = cfg.ssm_state
    d_inner, p, n_heads = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * N + n_heads     # z, x, B, C, dt
    return {
        "in_proj": layers.dense_init(ks[0], d, d_proj),
        "conv_w": layers.truncated_normal(ks[1], (cfg.ssm_conv, d_inner + 2 * N), 0.5),
        "conv_b": jnp.zeros((d_inner + 2 * N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01, jnp.float32))),
        "norm": layers.norm_init("rmsnorm", d_inner),
        "out_proj": layers.dense_init(ks[2], d_inner, d),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, p, n_heads = _dims(cfg)
    N = cfg.ssm_state
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    return z, xc, Bc, Cc, dt


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). Returns (y, new_state)."""
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else conv_state
    return jax.nn.silu(y + b), new_state


def mamba_apply(params, x, cfg, *, state=None):
    """x: (B,S,d). state: None (train/prefill from zero) or decode state dict
    {"conv": (B,K-1,C), "ssm": (B,H,p,N)}. Returns (y, new_state)."""
    B, S, d = x.shape
    N = cfg.ssm_state
    d_inner, p, H = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    dt_c = jnp.dtype(cfg.compute_dtype)

    zxbcdt = layers.dense(params["in_proj"], x, dtype=dt_c)
    z, xc, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1).astype(jnp.float32)
    conv_out, conv_state = _causal_conv(
        conv_in, params["conv_w"], params["conv_b"],
        None if state is None else state["conv"])
    xc, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                      # (H,) < 0
    loga = dt * A                                                      # (B,S,H) <= 0
    xh = xc.reshape(B, S, H, p)
    ssm0 = (jnp.zeros((B, H, p, N), jnp.float32)
            if state is None else state["ssm"].astype(jnp.float32))

    if S == 1:  # decode fast path: h = a*h + dt*x (x) B ; y = h . C
        a = jnp.exp(loga[:, 0])                                        # (B,H)
        dx = (dt[:, 0, :, None] * xh[:, 0])                            # (B,H,p)
        h = ssm0 * a[..., None, None] + dx[..., None] * Bc[:, 0, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0])
        y = y + params["D"][None, :, None] * xh[:, 0]
        y = y.reshape(B, 1, d_inner)
        new_state = {"conv": conv_state, "ssm": h}
    else:
        nc = -(-S // Q)
        pad = nc * Q - S
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bc_ = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cc_ = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
            loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
            dt_ = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        else:
            Bc_, Cc_, dt_ = Bc, Cc, dt
        # chunk layout: leading scan axis
        def cshape(t, feat):
            return t.reshape(B, nc, Q, *feat).transpose(1, 0, 2, *range(3, 3 + len(feat)))
        xh_c = cshape(xh, (H, p))
        B_c = cshape(Bc_, (N,))
        C_c = cshape(Cc_, (N,))
        la_c = cshape(loga, (H,))
        dt_chunks = cshape(dt_, (H,))

        def chunk_body(h, inp):
            xq, bq, cq, la, dtq = inp            # (B,Q,H,p) (B,Q,N) (B,Q,H)
            lc = jnp.cumsum(la, axis=1)          # (B,Q,H) cumulative log decay
            # intra-chunk: M[t,s] = exp(lc[t]-lc[s]) * (C_t.B_s) * dt_s, s<=t
            rel = lc[:, :, None, :] - lc[:, None, :, :]          # (B,Q,Q,H)
            tri = jnp.tril(jnp.ones((Q, Q), bool))
            M = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
            cb = jnp.einsum("bqn,bsn->bqs", cq, bq)              # (B,Q,Q)
            M = M * cb[..., None] * dtq[:, None, :, :]           # (B,Q,Q,H)
            y_intra = jnp.einsum("bqsh,bshp->bqhp", M, xq)
            # inter-chunk: y += C_t . (exp(lc[t]) h0)
            y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cq, h, jnp.exp(lc))
            # state update: h' = exp(lc[Q]) h0 + sum_s exp(lc[Q]-lc[s]) dt_s x_s B_s
            declast = jnp.exp(lc[:, -1])                          # (B,H)
            w_s = jnp.exp(lc[:, -1, None, :] - lc) * dtq          # (B,Q,H) <=? stable
            h_new = h * declast[..., None, None] + jnp.einsum(
                "bqh,bqhp,bqn->bhpn", w_s, xq, bq)
            return h_new, y_intra + y_inter

        hs, ys = jax.lax.scan(
            chunk_body, ssm0, (xh_c, B_c, C_c, la_c, dt_chunks))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, p)[:, :S]
        y = y + params["D"][None, None, :, None] * xh.reshape(B, nc * Q, H, p)[:, :S]
        y = y.reshape(B, S, d_inner)
        new_state = {"conv": conv_state, "ssm": hs}

    y = layers.apply_norm("rmsnorm", params["norm"], y.astype(dt_c))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_c)
    out = layers.dense(params["out_proj"], y, dtype=dt_c)
    return out, new_state


def mamba_state_init(cfg, batch, dtype=jnp.float32):
    d_inner, p, H = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state), jnp.float32),
        "ssm": jnp.zeros((batch, H, p, cfg.ssm_state), dtype),
    }
