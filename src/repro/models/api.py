"""Family-dispatching model facade used by train/serve/launch layers.

Batch contract (all jnp arrays):
  train:   {"tokens": (B,S_tok), "labels": (B,S_tok), ["embeds"|"frames"]}
  prefill: {"tokens": (B,S_tok), ["embeds"|"frames"]}
  decode:  {"tokens": (B,1)} + cache
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models import encdec, transformer


def init(cfg, key):
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def forward_hidden(cfg, params, batch: Dict[str, Any], *, attn_fn=None,
                   remat: str = "full", moe_impl: str = "einsum"):
    """Training forward to final hidden states. Returns (hidden, aux)."""
    if cfg.family == "encdec":
        enc_h = encdec.encode(cfg, params, batch["frames"], attn_fn=attn_fn,
                              remat=remat)
        hidden = encdec.decode_train(cfg, params, batch["tokens"], enc_h,
                                     attn_fn=attn_fn, remat=remat)
        return hidden, 0.0
    hidden, aux, _ = transformer.apply_lm(
        cfg, params, batch["tokens"], embeds=batch.get("embeds"),
        attn_fn=attn_fn, remat=remat, moe_impl=moe_impl)
    return hidden, aux


def unembed(cfg, params, hidden):
    if cfg.family == "encdec":
        dt = jnp.dtype(cfg.compute_dtype)
        return hidden.astype(dt) @ params["emb"]["table"].T.astype(dt)
    return transformer.unembed(cfg, params, hidden)


def unembed_table(cfg, params):
    """(d, V) matrix used by the chunked loss."""
    if cfg.family == "encdec" or cfg.tie_embeddings:
        return params["emb"]["table"].T
    return params["unembed"]["w"]


def prefill(cfg, params, batch, *, max_seq=None, remat: str = "full",
            attn_fn=None):
    if cfg.family == "encdec":
        return encdec.prefill_encdec(cfg, params, batch["frames"],
                                     batch["tokens"], max_seq=max_seq,
                                     remat=remat)
    return transformer.prefill_lm(cfg, params, batch["tokens"],
                                  embeds=batch.get("embeds"),
                                  max_seq=max_seq, remat=remat,
                                  attn_fn=attn_fn)


def decode(cfg, params, cache, tokens):
    if cfg.family == "encdec":
        return encdec.decode_encdec(cfg, params, cache, tokens)
    return transformer.decode_lm(cfg, params, cache, tokens)


def init_cache(cfg, batch: int, max_seq: int, *, s_enc: int = 0, dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        return encdec.init_dec_cache(cfg, batch, max_seq, s_enc or cfg.frontend_len,
                                     dtype=dtype)
    return transformer.init_cache(cfg, batch, max_seq, dtype=dtype)
