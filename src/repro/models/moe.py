"""Mixture-of-Experts block: top-k routing with capacity-bounded dispatch.

Two interchangeable implementations (bit-compatible where no tokens drop):

* ``einsum`` — GShard-style one-hot dispatch/combine einsums over token
  groups. Pure pjit, shards under any mesh; dispatch FLOPs overhead is
  ~(2/3)·T_group·cf/d_ff (≈4% for dbrx, ≈1% for grok). Default.
* ``scatter`` — sort + scatter-add dispatch (no one-hot FLOPs); candidate
  for §Perf hillclimbing (bandwidth-bound dispatch instead of FLOPs).

Expert weights are stored (E, d, ff); the *sharding rule* (parallel/
sharding.py) decides EP (experts over 'model') vs TP (d_ff over 'model').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx


def moe_init(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    std = 1.0 / jnp.sqrt(d)
    return {
        "router": layers.truncated_normal(ks[0], (d, E), 0.02),
        "wg": layers.truncated_normal(ks[1], (E, d, ff), std),
        "wu": layers.truncated_normal(ks[2], (E, d, ff), std),
        "wd": layers.truncated_normal(ks[3], (E, ff, d), 1.0 / jnp.sqrt(ff)),
    }


def _route(p, x2d, cfg):
    """x2d: (T, d) -> (weights (T,k), experts (T,k), probs (T,E))."""
    logits = x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w.astype(jnp.float32), idx, probs


def aux_load_balance_loss(probs, idx, num_experts):
    """Switch-style load-balance loss (mean fraction * mean prob * E)."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(idx.size, 1)
    mean_prob = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac * mean_prob)


def _expert_ffn(p, xin, cfg, dt):
    """xin: (..., E, C, d) -> (..., E, C, d) through per-expert SwiGLU."""
    g = jnp.einsum("...ecd,edf->...ecf", xin, p["wg"].astype(dt))
    u = jnp.einsum("...ecd,edf->...ecf", xin, p["wu"].astype(dt))
    act = jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g)
    return jnp.einsum("...ecf,efd->...ecd", act * u, p["wd"].astype(dt))


def moe_apply_einsum(p, x, cfg, *, group_size: int = 512):
    """x: (B, S, d) -> (y, aux_loss). One-hot grouped dispatch."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    dt = jnp.dtype(cfg.compute_dtype)
    T = B * S
    g = min(group_size, T)
    n_grp = T // g
    x2 = x.reshape(T, d)
    w, idx, probs = _route(p, x2, cfg)
    aux = aux_load_balance_loss(probs, idx, E)

    cap = max(1, int(g * k / E * cfg.capacity_factor))
    xg = x2.reshape(n_grp, g, d)
    wg_ = w.reshape(n_grp, g, k)
    ig = idx.reshape(n_grp, g, k)

    # position of each (token, choice) within its expert queue, per group
    onehot = jax.nn.one_hot(ig, E, dtype=jnp.int32)            # (n,g,k,E)
    flat = onehot.reshape(n_grp, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (n,g*k,E)
    pos = pos.reshape(n_grp, g, k, E)
    in_cap = (pos < cap) & (onehot > 0)

    # dispatch tensor (n, g, E, cap): 1 where token t goes to slot (e, c)
    # slot is zero for (token, choice, expert) triples that are not selected
    # or overflow capacity (their index is clamped to the dropped column).
    slot = jax.nn.one_hot(jnp.where(in_cap, pos, cap), cap + 1,
                          dtype=dt)[..., :cap]                  # (n,g,k,E,cap)
    disp = jnp.sum(slot, axis=2)                                # (n,g,E,cap)
    comb = jnp.sum(slot * wg_[..., None, None].astype(dt), axis=2)

    xin = jnp.einsum("ngec,ngd->necd", disp, xg.astype(dt))     # (n,E,cap,d)
    # §Perf (moe_token_local): pin the dispatched/combined buffers to the
    # token sharding. Without this the SPMD partitioner resolves the
    # dispatch einsums by replicating expert-sized intermediates and
    # gathering/reducing full (E, d, ff)-scale tensors once per layer per
    # microbatch ("involuntary full rematerialization"); with it, expert
    # weights stay sharded and only token-sized activations move.
    xin = pctx.constrain(xin, "moe_tokens")
    yout = _expert_ffn(p, xin, cfg, dt)                          # (n,E,cap,d)
    yout = pctx.constrain(yout, "moe_tokens")
    y = jnp.einsum("ngec,necd->ngd", comb, yout)
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_apply_scatter(p, x, cfg):
    """Sort/one-hot-free dispatch via scatter-add into capacity buffers."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    dt = jnp.dtype(cfg.compute_dtype)
    T = B * S
    x2 = x.reshape(T, d)
    w, idx, probs = _route(p, x2, cfg)
    aux = aux_load_balance_loss(probs, idx, E)

    cap = max(1, int(T * k / E * cfg.capacity_factor))
    flat_e = idx.reshape(-1)                                    # (T*k,)
    onehot_pos = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot_pos, axis=0) - 1)[jnp.arange(T * k), flat_e]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)                        # overflow slot

    tok = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, cap + 1, d), dt)
    buf = buf.at[flat_e, safe_pos].add(x2[tok].astype(dt))
    yout = _expert_ffn(p, buf[:, :cap][None], cfg, dt)[0]       # (E,cap,d)
    yout = jnp.concatenate([yout, jnp.zeros((E, 1, d), dt)], axis=1)
    gathered = yout[flat_e, safe_pos]                           # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((T, d), jnp.float32).at[tok].add(
        gathered.astype(jnp.float32) * w.reshape(-1)[:, None])
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_apply(p, x, cfg, *, impl: str = "einsum", group_size: int = 512):
    if impl == "einsum":
        return moe_apply_einsum(p, x, cfg, group_size=group_size)
    if impl == "scatter":
        return moe_apply_scatter(p, x, cfg)
    raise ValueError(impl)
