"""Attention: GQA projections + FlashAttention-style chunked online softmax.

``flash_ref`` is the pure-jnp online-softmax implementation (algorithmically
FlashAttention, scanned over KV chunks) used (a) as the oracle for the Pallas
kernels and (b) as the lowering path in the multi-pod dry-run (Pallas TPU
kernels do not lower on the CPU host platform; see DESIGN.md §8).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Reference implementations
# ---------------------------------------------------------------------------

def attention_naive(q, k, v, *, causal: bool, q_offset=0):
    """Materializing reference. q:(B,L,H,D) k/v:(B,S,Hkv,D) -> (B,L,H,D)."""
    B, L, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, L, Hkv, G, D)
    s = jnp.einsum("blhgd,bshd->bhgls", qg.astype(jnp.float32), k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(D)
    if causal:
        row = jnp.arange(L)[:, None] + q_offset
        col = jnp.arange(S)[None, :]
        s = jnp.where(col <= row, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgls,bshd->blhgd", p, v.astype(jnp.float32))
    return o.reshape(B, L, H, D).astype(q.dtype)


def flash_ref(q, k, v, *, causal: bool, q_offset=0, chunk: int = 512,
              pv_bf16: bool = False):
    """Online-softmax attention scanned over KV chunks (pure jnp).

    Never materializes the (L, S) score matrix for more than one KV chunk;
    this is the FlashAttention dataflow expressed at the XLA level.
    ``pv_bf16`` stores the probability tile at half width for the PV matmul
    (FA3 §5.2 does exactly this FP32->FP16 conversion before P@V) — §Perf
    knob that cuts the dominant score-tile HBM traffic.
    """
    B, L, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, L, Hkv, G, D).astype(jnp.float32) * (1.0 / math.sqrt(D))
    row = jnp.arange(L)[:, None] + q_offset

    def body(carry, kv):
        m, l, acc, j = carry
        kj, vj = kv
        s = jnp.einsum("blhgd,bchd->blhgc", qg, kj.astype(jnp.float32))
        col = j * chunk + jnp.arange(chunk)[None, :]          # (1, chunk)
        if causal:
            mask = (col > row) | (col >= S)                   # (L, chunk)
        else:
            mask = jnp.broadcast_to(col >= S, (L, chunk))
        s = jnp.where(mask[None, :, None, None, :], NEG_INF, s)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if pv_bf16:
            acc_new = acc * corr[..., None] + jnp.einsum(
                "blhgc,bchd->blhgd", p.astype(jnp.bfloat16),
                vj.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
        else:
            acc_new = acc * corr[..., None] + jnp.einsum(
                "blhgc,bchd->blhgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new, j + 1), None

    m0 = jnp.full((B, L, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, L, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, L, Hkv, G, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, L, H, D).astype(q.dtype)


def decode_attend(q, k_cache, v_cache, cache_len, *, q_offset=None):
    """Single-token decode over a (possibly longer-than-filled) KV cache.

    q: (B, 1, H, D); caches: (B, S_max, Hkv, D); cache_len: scalar or (B,)
    Positions >= cache_len are masked.
    """
    B, L, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, L, Hkv, G, D).astype(jnp.float32) * (1.0 / math.sqrt(D))
    s = jnp.einsum("blhgd,bshd->blhgs", qg, k_cache.astype(jnp.float32))
    # seq-sharded caches: keep scores sharded on S (partial-softmax psum)
    # instead of letting XLA all-gather the cache per layer
    s = pctx.constrain(s, "scores_dec")
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len), (B,))[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("blhgs,bshd->blhgd", p, v_cache.astype(jnp.float32))
    o = o / jnp.sum(p, axis=-1)[..., None]
    return o.reshape(B, L, H, D).astype(q.dtype)


def decode_attend_partial(q, k_shard, v_shard, valid_mask):
    """Shard-local flash decode for sequence-sharded KV caches (SP).

    Returns (o_partial(fp32), m(fp32), l(fp32)) for a distributed
    log-sum-exp merge across sequence shards (see merge_partial_attn).
    q: (B,1,H,D); k/v_shard: (B,S_loc,Hkv,D); valid_mask: (B,S_loc) bool.
    """
    B, L, H, D = q.shape
    Hkv = k_shard.shape[2]
    G = H // Hkv
    qg = q.reshape(B, L, Hkv, G, D).astype(jnp.float32) * (1.0 / math.sqrt(D))
    s = jnp.einsum("blhgd,bshd->blhgs", qg, k_shard.astype(jnp.float32))
    s = jnp.where(valid_mask[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("blhgs,bshd->blhgd", p, v_shard.astype(jnp.float32))
    return o, m, l


def merge_partial_attn(o_parts, m_parts, l_parts, axis=0):
    """Merge per-shard (o, m, l) partials along a leading shard axis."""
    m = jnp.max(m_parts, axis=axis)
    corr = jnp.exp(m_parts - jnp.expand_dims(m, axis))
    l = jnp.sum(l_parts * corr, axis=axis)
    o = jnp.sum(o_parts * corr[..., None], axis=axis)
    return o / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def attn_init(key, cfg):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d, cfg.num_heads * hd, bias=cfg.qkv_bias or cfg.bias),
        "wk": layers.dense_init(ks[1], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias or cfg.bias),
        "wv": layers.dense_init(ks[2], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias or cfg.bias),
        "wo": layers.dense_init(ks[3], cfg.num_heads * hd, d, bias=cfg.bias),
    }


def attn_apply(p, x, cfg, *, positions, kv_cache=None, cache_index=None,
               cross_kv=None, attn_fn=None, use_rope=True):
    """Returns (out, new_kv) where new_kv is (k, v) of this call's tokens.

    kv_cache: optional (k_cache, v_cache) of shape (B, S_max, Hkv, D) --
    decode path (x is (B,1,d)). cross_kv: precomputed (k, v) for
    cross-attention (no rope, no cache write).
    """
    B, L, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    q = layers.dense(p["wq"], x, dtype=dt).reshape(B, L, H, hd)
    if cross_kv is not None:
        k, v = cross_kv
        if use_rope:
            q = layers.rope(q, positions, cfg.rope_theta)
        o = (attn_fn or flash_ref)(q, k, v, causal=False)
        return layers.dense(p["wo"], o.reshape(B, L, H * hd), dtype=dt), None

    k = layers.dense(p["wk"], x, dtype=dt).reshape(B, L, Hkv, hd)
    v = layers.dense(p["wv"], x, dtype=dt).reshape(B, L, Hkv, hd)
    if use_rope:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        idx = cache_index
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), idx, axis=1)
        o = decode_attend(q, k_cache, v_cache, idx + L)
        out = layers.dense(p["wo"], o.reshape(B, L, H * hd), dtype=dt)
        return out, (k_cache, v_cache)

    o = (attn_fn or flash_ref)(q, k, v, causal=cfg.causal)
    out = layers.dense(p["wo"], o.reshape(B, L, H * hd), dtype=dt)
    # keep collected KV sharded (prefill cache assembly): without this the
    # scan's stacked ys replicate over 'model' when Hkv < TP degree
    return out, (pctx.constrain(k, "kv_collect"), pctx.constrain(v, "kv_collect"))
