"""LM-family model assembly: dense / MoE / VLM / hybrid(Zamba2) / SSM(RWKV6).

All stacks scan over layers (params carry a leading L dim) with a
configurable remat policy; decode threads per-layer caches through the scan.

Public API (used by launch/, train/, serve/):
    init_lm(cfg, key)                       -> params
    apply_lm(cfg, params, tokens, ...)      -> (hidden, aux)        train fwd
    prefill_lm(cfg, params, tokens, ...)    -> (hidden, cache)
    decode_lm(cfg, params, cache, tokens)   -> (logits, cache)      1 new token
    init_cache(cfg, batch, max_seq)         -> cache pytree
    unembed(cfg, params, hidden)            -> logits
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe, rwkv
from repro.parallel import ctx as pctx

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


# ---------------------------------------------------------------------------
# Per-layer blocks
# ---------------------------------------------------------------------------

def _block_init(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": layers.norm_init(cfg.norm, cfg.d_model),
        "attn": attention.attn_init(ks[0], cfg),
        "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        p["mlp"] = layers.mlp_init(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff, bias=cfg.bias)
    return p


def _block_apply(p, x, cfg, *, positions, kv=None, cache_index=None,
                 attn_fn=None, moe_impl="einsum"):
    h = layers.apply_norm(cfg.norm, p["attn_norm"], x)
    h, new_kv = attention.attn_apply(
        p["attn"], h, cfg, positions=positions, kv_cache=kv,
        cache_index=cache_index, attn_fn=attn_fn)
    x = x + h
    h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
    if cfg.family == "moe":
        h, aux = moe.moe_apply(p["moe"], h, cfg, impl=moe_impl)
    else:
        h, aux = layers.apply_mlp(cfg.mlp, p["mlp"], h), 0.0
    return x + h, new_kv, aux


def _mamba_layer_init(key, cfg):
    return {"norm": layers.norm_init(cfg.norm, cfg.d_model),
            "mamba": mamba.mamba_init(key, cfg)}


def _mamba_layer_apply(p, x, cfg, state=None):
    h = layers.apply_norm(cfg.norm, p["norm"], x)
    h, new_state = mamba.mamba_apply(p["mamba"], h, cfg, state=state)
    return x + h, new_state


def _rwkv_layer_init(key, cfg):
    return {"block": rwkv.rwkv_init(key, cfg),
            "ln1": layers.norm_init("layernorm", cfg.d_model),
            "ln2": layers.norm_init("layernorm", cfg.d_model)}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_lm(cfg, key):
    ks = jax.random.split(key, 8)
    params = {"emb": layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model),
              "final_norm": layers.norm_init(cfg.norm, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["unembed"] = layers.dense_init(ks[1], cfg.d_model, cfg.vocab_size)

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(partial(_block_init, cfg=cfg), ks[2], cfg.num_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(partial(_rwkv_layer_init, cfg=cfg), ks[2], cfg.num_layers)
        params["ln0"] = layers.norm_init("layernorm", cfg.d_model)
    elif cfg.family == "hybrid":
        params["prologue"] = _stack_init(partial(_mamba_layer_init, cfg=cfg),
                                         ks[2], cfg.hybrid_prologue)
        params["groups"] = jax.vmap(
            lambda k: _stack_init(partial(_mamba_layer_init, cfg=cfg), k,
                                  cfg.hybrid_mamba_per_group)
        )(jax.random.split(ks[3], cfg.hybrid_groups))
        params["shared_attn"] = _block_init(ks[4], cfg)  # ONE weight set, reused
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    hd, hkv = cfg.head_dim, cfg.num_kv_heads
    if cfg.family in ("dense", "moe", "vlm"):
        return {
            "k": jnp.zeros((cfg.num_layers, batch, max_seq, hkv, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, max_seq, hkv, hd), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        st = rwkv.rwkv_state_init(cfg, batch)
        return {"layers": jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape), st),
                "idx": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        st = mamba.mamba_state_init(cfg, batch)
        stack = lambda t, n: jnp.broadcast_to(t, (n,) + t.shape)
        return {
            "prologue": jax.tree.map(lambda t: stack(t, cfg.hybrid_prologue), st),
            "groups": jax.tree.map(
                lambda t: stack(stack(t, cfg.hybrid_mamba_per_group), cfg.hybrid_groups), st),
            "attn_k": jnp.zeros((cfg.hybrid_groups, batch, max_seq, hkv, hd), dtype),
            "attn_v": jnp.zeros((cfg.hybrid_groups, batch, max_seq, hkv, hd), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, embeds):
    x = layers.embed(params["emb"], tokens, dtype=jnp.dtype(cfg.compute_dtype))
    if embeds is not None:  # vlm/frontend stub: precomputed prefix embeddings
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def _scan_blocks(cfg, body, x, xs, remat: str):
    if remat != "none":
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    return jax.lax.scan(body, x, xs)


def apply_lm(cfg, params, tokens, *, embeds=None, attn_fn=None,
             remat: str = "full", moe_impl: str = "einsum",
             collect_kv: bool = False):
    """Training/prefill forward. Returns (hidden, aux, kv_stack|None)."""
    x = _embed_inputs(cfg, params, tokens, embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, blk):
            h, aux = carry
            h, kv, a = _block_apply(blk, h, cfg, positions=positions,
                                    attn_fn=attn_fn, moe_impl=moe_impl)
            return (pctx.constrain(h), aux + a), (kv if collect_kv else None)
        (x, aux), kvs = _scan_blocks(cfg, body, (x, 0.0), params["blocks"], remat)
    elif cfg.family == "ssm":
        x = layers.apply_norm("layernorm", params["ln0"], x)
        st0 = rwkv.rwkv_state_init(cfg, B)

        def body(h, blk):
            h, st = rwkv.rwkv_block(blk["block"], h, cfg,
                                    {"ln1": blk["ln1"], "ln2": blk["ln2"]}, state=st0)
            return pctx.constrain(h), (st if collect_kv else None)
        x, kvs = _scan_blocks(cfg, body, x, params["blocks"], remat)
        aux = 0.0
    elif cfg.family == "hybrid":
        st0 = mamba.mamba_state_init(cfg, B)

        def mbody(h, blk):
            h, st = _mamba_layer_apply(blk, h, cfg, state=st0)
            return pctx.constrain(h), (st if collect_kv else None)
        x, pro_sts = _scan_blocks(cfg, mbody, x, params["prologue"], remat)
        shared = params["shared_attn"]

        def gbody(h, blk):
            h, msts = _scan_blocks(cfg, mbody, h, blk,
                                   "full" if remat != "none" else "none")
            h, kv, _ = _block_apply(shared, h, cfg, positions=positions,
                                    attn_fn=attn_fn)
            return pctx.constrain(h), ((msts, kv) if collect_kv else None)
        x, grp = _scan_blocks(cfg, gbody, x, params["groups"], remat)
        kvs = (pro_sts, grp)
        aux = 0.0
    else:
        raise ValueError(cfg.family)

    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return x, aux, kvs


def unembed(cfg, params, hidden):
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        return hidden.astype(dt) @ params["emb"]["table"].T.astype(dt)
    return layers.dense(params["unembed"], hidden, dtype=dt)


def prefill_lm(cfg, params, tokens, *, embeds=None, attn_fn=None,
               max_seq: Optional[int] = None, remat: str = "full"):
    """Forward + build decode cache. Returns (hidden, cache)."""
    hidden, _, kvs = apply_lm(cfg, params, tokens, embeds=embeds,
                              attn_fn=attn_fn, remat=remat, collect_kv=True)
    B = tokens.shape[0]
    S = hidden.shape[1]
    max_seq = max_seq or S
    cache = init_cache(cfg, B, max_seq, dtype=jnp.dtype(cfg.compute_dtype))
    if cfg.family in ("dense", "moe", "vlm"):
        k, v = kvs  # (L,B,S,hkv,hd)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
    elif cfg.family == "ssm":
        cache["layers"] = kvs
    elif cfg.family == "hybrid":
        pro_sts, (msts, kv) = kvs
        cache["prologue"] = pro_sts
        cache["groups"] = msts
        k, v = kv
        cache["attn_k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["attn_k"], k.astype(cache["attn_k"].dtype), 0, axis=2)
        cache["attn_v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["attn_v"], v.astype(cache["attn_v"].dtype), 0, axis=2)
    cache["idx"] = jnp.asarray(S, jnp.int32)
    return hidden, cache


def decode_lm(cfg, params, cache, tokens):
    """One decode step. tokens: (B, 1). Returns (logits, new_cache)."""
    x = _embed_inputs(cfg, params, tokens, None)
    idx = cache["idx"]
    positions = idx + jnp.zeros((1, 1), jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, blk_kv):
            blk, k, v = blk_kv
            h, (k2, v2), _ = _block_apply(blk, h, cfg, positions=positions,
                                          kv=(k, v), cache_index=idx)
            return pctx.constrain(h, "residual_dec"), (k2, v2)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=ks, v=vs, idx=idx + 1)
    elif cfg.family == "ssm":
        x = layers.apply_norm("layernorm", params["ln0"], x)

        def body(h, blk_st):
            blk, st = blk_st
            h, st2 = rwkv.rwkv_block(blk["block"], h, cfg,
                                     {"ln1": blk["ln1"], "ln2": blk["ln2"]}, state=st)
            return pctx.constrain(h, "residual_dec"), st2
        x, sts = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        new_cache = dict(cache, layers=sts, idx=idx + 1)
    elif cfg.family == "hybrid":
        def mbody(h, blk_st):
            blk, st = blk_st
            h, st2 = _mamba_layer_apply(blk, h, cfg, state=st)
            return pctx.constrain(h, "residual_dec"), st2
        x, pro_sts = jax.lax.scan(mbody, x, (params["prologue"], cache["prologue"]))
        shared = params["shared_attn"]

        def gbody(h, inp):
            blk, msts, k, v = inp
            h, msts2 = jax.lax.scan(mbody, h, (blk, msts))
            h, (k2, v2), _ = _block_apply(shared, h, cfg, positions=positions,
                                          kv=(k, v), cache_index=idx)
            return pctx.constrain(h, "residual_dec"), (msts2, k2, v2)
        x, (gsts, ks, vs) = jax.lax.scan(
            gbody, x, (params["groups"], cache["groups"],
                       cache["attn_k"], cache["attn_v"]))
        new_cache = dict(cache, prologue=pro_sts, groups=gsts,
                         attn_k=ks, attn_v=vs, idx=idx + 1)
    else:
        raise ValueError(cfg.family)

    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return unembed(cfg, params, x), new_cache
