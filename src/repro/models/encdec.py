"""Encoder-decoder (Whisper-large-v3 backbone). Conv/mel frontend is a STUB:
the encoder consumes precomputed frame embeddings (B, S_enc, d) supplied by
``input_specs`` per the assignment.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.parallel import ctx as pctx
from repro.models.transformer import _scan_blocks, _stack_init


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": layers.norm_init(cfg.norm, cfg.d_model),
        "attn": attention.attn_init(ks[0], cfg),
        "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model),
        "mlp": layers.mlp_init(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff, bias=cfg.bias),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    p = _enc_block_init(ks[0], cfg)
    p["xattn_norm"] = layers.norm_init(cfg.norm, cfg.d_model)
    p["xattn"] = attention.attn_init(ks[1], cfg)
    return p


def init_encdec(cfg, key):
    ks = jax.random.split(key, 6)
    return {
        "emb": layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "pos_dec": layers.truncated_normal(ks[1], (65536, cfg.d_model), 0.01),
        "enc_blocks": _stack_init(partial(_enc_block_init, cfg=cfg), ks[2], cfg.enc_layers),
        "dec_blocks": _stack_init(partial(_dec_block_init, cfg=cfg), ks[3], cfg.dec_layers),
        "enc_norm": layers.norm_init(cfg.norm, cfg.d_model),
        "dec_norm": layers.norm_init(cfg.norm, cfg.d_model),
    }


def encode(cfg, params, frames, *, attn_fn=None, remat="full"):
    """frames: (B, S_enc, d) precomputed frame embeddings (stub frontend).
    The whisper encoder attends bidirectionally (non-causal)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(x.shape[1])[None, :]
    nc_attn = attn_fn or (lambda q, k, v, **kw: attention.flash_ref(
        q, k, v, causal=False))

    def body(h, blk):
        a = layers.apply_norm(cfg.norm, blk["attn_norm"], h)
        a, _ = attention.attn_apply(blk["attn"], a, cfg, positions=positions,
                                    attn_fn=nc_attn, use_rope=False)
        h = h + a
        m = layers.apply_norm(cfg.norm, blk["mlp_norm"], h)
        h = h + layers.apply_mlp(cfg.mlp, blk["mlp"], m)
        return pctx.constrain(h), None

    x, _ = _scan_blocks(cfg, body, x, params["enc_blocks"], remat)
    return layers.apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(blk, h, cfg, enc_kv, *, positions, kv=None, cache_index=None,
               attn_fn=None):
    a = layers.apply_norm(cfg.norm, blk["attn_norm"], h)
    a, new_kv = attention.attn_apply(blk["attn"], a, cfg, positions=positions,
                                     kv_cache=kv, cache_index=cache_index,
                                     attn_fn=attn_fn, use_rope=False)
    h = h + a
    xa = layers.apply_norm(cfg.norm, blk["xattn_norm"], h)
    xa, _ = attention.attn_apply(blk["xattn"], xa, cfg, positions=positions,
                                 cross_kv=enc_kv, use_rope=False)
    h = h + xa
    m = layers.apply_norm(cfg.norm, blk["mlp_norm"], h)
    h = h + layers.apply_mlp(cfg.mlp, blk["mlp"], m)
    return h, new_kv


def _cross_kv(cfg, blk, enc_h):
    B, S_enc, _ = enc_h.shape
    dt = jnp.dtype(cfg.compute_dtype)
    k = layers.dense(blk["xattn"]["wk"], enc_h, dtype=dt).reshape(
        B, S_enc, cfg.num_kv_heads, cfg.head_dim)
    v = layers.dense(blk["xattn"]["wv"], enc_h, dtype=dt).reshape(
        B, S_enc, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def decode_train(cfg, params, tokens, enc_h, *, attn_fn=None, remat="full"):
    """Teacher-forced decoder pass. Returns hidden (B, S_dec, d)."""
    x = layers.embed(params["emb"], tokens, dtype=jnp.dtype(cfg.compute_dtype))
    S = tokens.shape[1]
    x = x + params["pos_dec"][:S].astype(x.dtype)[None]
    positions = jnp.arange(S)[None, :]

    def body(h, blk):
        ekv = _cross_kv(cfg, blk, enc_h)
        h, _ = _dec_block(blk, h, cfg, ekv, positions=positions, attn_fn=attn_fn)
        return pctx.constrain(h), None

    x, _ = _scan_blocks(cfg, body, x, params["dec_blocks"], remat)
    return layers.apply_norm(cfg.norm, params["dec_norm"], x)


def apply_encdec(cfg, params, frames, tokens, *, attn_fn=None, remat="full"):
    enc_h = encode(cfg, params, frames, attn_fn=attn_fn, remat=remat)
    hidden = decode_train(cfg, params, tokens, enc_h, attn_fn=attn_fn, remat=remat)
    dt = jnp.dtype(cfg.compute_dtype)
    return hidden.astype(dt) @ params["emb"]["table"].T.astype(dt)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with self-cache + fixed cross kv
# ---------------------------------------------------------------------------

def init_dec_cache(cfg, batch, max_seq, s_enc, dtype=jnp.bfloat16):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    L = cfg.dec_layers
    return {
        "k": jnp.zeros((L, batch, max_seq, hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_seq, hkv, hd), dtype),
        "cross_k": jnp.zeros((L, batch, s_enc, hkv, hd), dtype),
        "cross_v": jnp.zeros((L, batch, s_enc, hkv, hd), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def prefill_encdec(cfg, params, frames, tokens, *, max_seq=None, remat="full"):
    enc_h = encode(cfg, params, frames, remat=remat)
    B, S = tokens.shape
    max_seq = max_seq or S
    cache = init_dec_cache(cfg, B, max_seq, enc_h.shape[1],
                           dtype=jnp.dtype(cfg.compute_dtype))
    x = layers.embed(params["emb"], tokens, dtype=jnp.dtype(cfg.compute_dtype))
    x = x + params["pos_dec"][:S].astype(x.dtype)[None]
    positions = jnp.arange(S)[None, :]

    def body(h, blk):
        ekv = _cross_kv(cfg, blk, enc_h)
        h, kv = _dec_block(blk, h, cfg, ekv, positions=positions)
        return pctx.constrain(h), (kv, ekv)

    x, ((ks, vs), (cks, cvs)) = _scan_blocks(cfg, body, x, params["dec_blocks"], remat)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)
    cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
    cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)
    cache["idx"] = jnp.asarray(S, jnp.int32)
    hidden = layers.apply_norm(cfg.norm, params["dec_norm"], x)
    return hidden, cache


def decode_encdec(cfg, params, cache, tokens):
    """tokens: (B,1). Cross-attention reads the cached encoder projections."""
    B = tokens.shape[0]
    idx = cache["idx"]
    x = layers.embed(params["emb"], tokens, dtype=jnp.dtype(cfg.compute_dtype))
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], idx, 1).astype(x.dtype)[None, 0]
    positions = idx + jnp.zeros((1, 1), jnp.int32)

    def body(h, inp):
        blk, k, v, ck, cv = inp
        h, (k2, v2) = _dec_block(blk, h, cfg, (ck, cv), positions=positions,
                                 kv=(k, v), cache_index=idx)
        return pctx.constrain(h, "residual_dec"), (k2, v2)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, k=ks, v=vs, idx=idx + 1)
    x = layers.apply_norm(cfg.norm, params["dec_norm"], x)
    dt = jnp.dtype(cfg.compute_dtype)
    logits = x.astype(dt) @ params["emb"]["table"].T.astype(dt)
    return logits, new_cache
