"""Whisper-large-v3: enc-dec, 32L enc + 32L dec, d1280 20H (MHA kv=20)
d_ff=5120 vocab=51866. Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    enc_layers=32,
    dec_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    mlp="gelu_mlp",
    bias=True,
    causal=True,
    frontend="frame_stub",
    frontend_len=1500,
    notes="enc-dec; conv frontend stubbed (precomputed frame embeddings)",
)
