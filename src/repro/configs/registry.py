"""Architecture registry: ``--arch <id>`` ids map to ModelConfigs."""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs import (
    dbrx_132b, grok_1_314b, olmo_1b, command_r_plus_104b, minicpm_2b,
    qwen2_5_3b, zamba2_7b, pixtral_12b, rwkv6_7b, whisper_large_v3, llama3,
)

# The 10 assigned architectures (+ the paper's own llama3-8b as an extra).
ARCHS: Dict[str, ModelConfig] = {
    "dbrx-132b": dbrx_132b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "olmo-1b": olmo_1b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "minicpm-2b": minicpm_2b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "pixtral-12b": pixtral_12b.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "llama3-8b": llama3.CONFIG,   # extra: the paper's validation family
}

ASSIGNED = [a for a in ARCHS if a != "llama3-8b"]


def get(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def cells(include_extra: bool = False) -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All (arch x shape) cells. Yields (cfg, shape, supported, reason)."""
    names = list(ARCHS) if include_extra else ASSIGNED
    for a in names:
        cfg = ARCHS[a]
        for shape in SHAPES.values():
            ok, why = cfg.supports_shape(shape)
            yield cfg, shape, ok, why
