"""Config dataclasses for architectures, input shapes, and runs.

Every assigned architecture is expressed as a :class:`ModelConfig`. The full
configs are exercised only through the multi-pod dry-run (ShapeDtypeStruct,
no allocation); smoke tests use :meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is paired with these four shapes.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True

    # --- norm / mlp ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"              # swiglu | geglu | gelu_mlp
    tie_embeddings: bool = False
    bias: bool = False               # linear-layer bias (whisper: True)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # zamba-style hybrid: prologue mamba layers, then groups of
    # [mamba_per_group mamba + 1 SHARED attention block]
    hybrid_prologue: int = 0
    hybrid_groups: int = 0
    hybrid_mamba_per_group: int = 0

    # --- rwkv ---
    rwkv_head_dim: int = 64

    # --- enc-dec ---
    enc_layers: int = 0              # encoder layers (encdec only)
    dec_layers: int = 0

    # --- vlm / audio stub frontend ---
    frontend: str = "none"           # none | patch_stub | frame_stub
    frontend_len: int = 0            # positions supplied as precomputed embeds

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- schedule (minicpm WSD) ---
    lr_schedule: str = "cosine"      # cosine | wsd

    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def q_group_size(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Whether 500k-token decode is architecturally sensible."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    def supports_shape(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """(supported, reason-if-not)."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "pure full-attention arch; long_500k skipped per assignment"
        if self.family == "encdec" and shape.kind == "train" and shape.seq_len > 8192:
            return True, ""
        return True, ""

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline maths)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.mlp in ("swiglu", "geglu"):
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        emb = v * d * (1 if self.tie_embeddings else 2)

        if self.family == "moe":
            mlp = self.num_experts * mlp_dense + d * self.num_experts  # + router
            per_layer = attn + mlp
            return self.num_layers * per_layer + emb
        if self.family == "ssm":  # rwkv6
            d_in = d
            tmix = 4 * d * d_in + 6 * d * 32 * 2 + d_in  # r,k,v,o + lora-ish mixers
            cmix = 2 * d * self.d_ff
            return self.num_layers * (tmix + cmix) + emb
        if self.family == "hybrid":
            d_inner = self.ssm_expand * d
            mamba = d * 2 * d_inner + d_inner * d + d_inner * (self.ssm_conv + 3) \
                + 2 * d_inner * self.ssm_state
            n_mamba = self.hybrid_prologue + self.hybrid_groups * self.hybrid_mamba_per_group
            shared_attn = attn + mlp_dense  # ONE shared block
            return n_mamba * mamba + shared_attn + emb
        if self.family == "encdec":
            enc = self.enc_layers * (attn + mlp_dense)
            dec = self.dec_layers * (2 * attn + mlp_dense)  # self + cross
            return enc + dec + emb
        # dense / vlm backbone
        return self.num_layers * (attn + mlp_dense) + emb

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        mlp_dense = 3 * d * self.d_ff
        per_layer = attn + self.experts_per_token * mlp_dense
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.num_layers * per_layer + emb

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.family == "moe":
            kw.update(num_experts=4, experts_per_token=2)
        if self.family in ("hybrid",):
            kw.update(hybrid_prologue=1, hybrid_groups=1, hybrid_mamba_per_group=1,
                      ssm_state=8, num_layers=3)
        if self.family == "ssm":
            kw.update(rwkv_head_dim=16, num_layers=2)
        if self.family == "encdec":
            kw.update(enc_layers=2, dec_layers=2, num_layers=2)
        if self.frontend != "none":
            kw.update(frontend_len=8)
        if self.num_kv_heads > 4:
            kw.update(num_kv_heads=4)
        if self.num_kv_heads and self.num_kv_heads == self.num_heads:
            kw.update(num_kv_heads=4)  # keep MHA shape-consistent
        return ModelConfig(**kw)
