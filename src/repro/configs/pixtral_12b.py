"""Pixtral-12B: 40L d5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Mistral-Nemo-style backbone (head_dim=128 explicit); pixtral-ViT frontend is
a STUB — input_specs() provides precomputed patch embeddings.
[hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    mlp="swiglu",
    frontend="patch_stub",
    frontend_len=1024,   # patch positions provided as precomputed embeddings
    notes="pixtral-ViT frontend stubbed; mistral-nemo backbone",
)
