"""Zamba2-7B: 81L d3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Hybrid Mamba2 backbone with a SHARED attention block applied periodically.
Layer layout here: 3 Mamba2 prologue + 13 x [5 Mamba2 + shared attn] = 81.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    hybrid_prologue=3,
    hybrid_groups=13,
    hybrid_mamba_per_group=5,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
    notes="Mamba2 + shared attention blocks (one weight set reused)",
)
