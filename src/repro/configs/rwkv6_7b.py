"""RWKV6-7B (Finch): 32L d4096 attention-free, d_ff=14336 vocab=65536.
Data-dependent decay linear RNN; head size 64 -> 64 heads.
[arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # rwkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    norm="layernorm",
    mlp="gelu_mlp",        # rwkv channel-mix (squared relu in paper; gelu-family)
    notes="Finch: data-dependent decay; attention-free",
)
