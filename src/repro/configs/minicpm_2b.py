"""MiniCPM-2B: 40L d2304 36H (MHA kv=36) d_ff=5760 vocab=122753, llama-like,
trained with the WSD schedule. [arXiv:2404.06395]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
    lr_schedule="wsd",
    notes="WSD schedule; llama-like",
)
