"""DBRX-132B: 40L d6144 48H (GQA kv=8) d_ff=10752/expert, MoE 16e top-4
(fine-grained experts). [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    norm="layernorm",
    mlp="swiglu",
    notes="fine-grained MoE, 16 experts top-4",
)
