"""Llama-3 family attention workloads (paper Table 6) used by the Sim-FA
validation benchmarks (Figs. 6, 8, 9), plus a full llama3-8b ModelConfig as
an extra selectable arch."""
from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class AttnWorkload:
    """One FlashAttention kernel invocation (paper Table 1/6 notation)."""
    name: str
    B: int          # batch
    L: int          # query length
    S: int          # kv length
    H_kv: int       # kv heads
    G: int          # query group size (Q heads per KV head)
    D: int          # head dim
    P: int = 2      # bytes per element (fp16/bf16)
    causal: bool = False


# Table 6 of the paper.
LLAMA3_8B = dict(H_q=32, H_kv=8, G=4, D=128)
LLAMA3_70B = dict(H_q=64, H_kv=8, G=8, D=128)
LLAMA3_405B = dict(H_q=128, H_kv=8, G=16, D=128)

FAMILY = {"8B": LLAMA3_8B, "70B": LLAMA3_70B, "405B": LLAMA3_405B}


def workload(model: str, seqlen: int, batch: int = 1, causal: bool = False) -> AttnWorkload:
    f = FAMILY[model]
    return AttnWorkload(name=f"llama3-{model}-s{seqlen}", B=batch, L=seqlen,
                        S=seqlen, H_kv=f["H_kv"], G=f["G"], D=f["D"],
                        causal=causal)


CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm="rmsnorm",
    mlp="swiglu",
    notes="paper's own validation model family (Table 6)",
)
