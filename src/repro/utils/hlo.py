"""HLO text analysis: FLOPs / bytes / collective-traffic for the roofline.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers model under-reports flops and bytes by ~num_layers. All
three roofline terms therefore come from walking ``compiled.as_text()``
ourselves:

  * ``collective_bytes`` — wire bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * ``hlo_cost`` — dot/convolution FLOPs plus "bytes accessed" (operand +
    result bytes of every materialization-boundary op, i.e. post-fusion
    instructions; fusion internals are on-chip and not counted);

both multiplying ops inside while bodies by the loop trip count.

Trip counts are recovered from the loop condition: XLA canonical while
conditions compare the induction variable against a constant; we take the
largest integer constant compared in the condition computation. This is a
heuristic (documented in DESIGN.md §8) validated by tests against known
scan lengths.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a one-element list of dicts (per partition), newer ones
    return the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^\s*%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->", line)
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _while_bodies_with_trips(hlo: str, comps) -> Dict[str, int]:
    """body computation name -> trip count.

    Primary source: XLA's ``backend_config={"known_trip_count":{"n":N}}``
    annotation on the while op; fallback: the largest integer constant in
    the loop-condition computation (canonical scan loops compare the
    induction variable against the length)."""
    out: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = re.search(
            r"while\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", line)
        if not m:
            continue
        cond, body = m.group(1), m.group(2)
        kt = re.search(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)', line)
        if kt:
            out[body] = int(kt.group(1))
            continue
        trip = 1
        for cline in comps.get(cond, []):
            for c in re.finditer(r"constant\((\d+)\)", cline):
                trip = max(trip, int(c.group(1)))
        out[body] = trip
    return out


def _called_by(comps) -> Dict[str, List[str]]:
    """computation -> computations it calls (body/branches/called comps)."""
    calls = defaultdict(list)
    names = set(comps)
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:body|condition|to_apply|branch_computations=\{[^}]*)"
                                 r"=?%?([\w\.\-]+)", line):
                if m.group(1) in names:
                    calls[name].append(m.group(1))
    return calls


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Returns {collective_kind: bytes, "total": bytes} with while-loop
    multipliers applied and CPU-backend precision-simulation fusions
    counted at semantic width (see _roundtrip_factor)."""
    comps = _split_computations(hlo)
    trips = _while_bodies_with_trips(hlo, comps)
    calls = _called_by(comps)
    parsed = {name: _parse_computation(lines) for name, lines in comps.items()}
    factors = _semantic_factors(parsed)

    # propagate multipliers: a computation called from a while body inherits
    # the body's trip count (one level of nesting handled transitively)
    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    for body, t in trips.items():
        stack = [(body, float(t))]
        seen = set()
        while stack:
            name, m = stack.pop()
            if name in seen:
                continue
            seen.add(name)
            mult[name] = max(mult[name], m)
            for child in calls.get(name, []):
                child_t = trips.get(child, 1)
                stack.append((child, m * child_t))

    out: Dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        m = mult[name]
        for line in lines:
            for kind in COLLECTIVES:
                if not re.search(rf"\s{re.escape(kind)}(-start)?\(", line):
                    continue
                # scheduled HLO: '%x = f32[a,b]{layout} all-gather(%y), ...'
                # operands are bare refs; take the RESULT shape and convert
                # to approximate per-device wire bytes via the group size.
                mm = re.search(rf"=\s*(.+?)\s+{re.escape(kind)}(?:-start)?\(",
                               line)
                b = _shape_bytes(mm.group(1)) if mm else 0
                # semantic width: a collective fed by a bf16->f32 roundtrip
                # fusion moves bf16 on real (TPU/GPU) hardware
                om = re.search(rf"{re.escape(kind)}(?:-start)?\(%([\w\.\-]+)",
                               line)
                if om and om.group(1) in factors:
                    b *= factors[om.group(1)]
                g = _group_size(line)
                if kind == "all-reduce":
                    wire = 2.0 * b * (g - 1) / max(g, 1)
                elif kind == "all-gather":
                    wire = b * (g - 1) / max(g, 1)
                elif kind == "reduce-scatter":
                    wire = b * (g - 1)            # result is 1/g of operand
                elif kind == "all-to-all":
                    wire = b * (g - 1) / max(g, 1)
                else:                              # collective-permute
                    wire = b
                out[kind] += wire * m
                out["count_" + kind] += m
                break
    out["total"] = sum(v for k, v in out.items() if k in COLLECTIVES)
    return dict(out)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


# ---------------------------------------------------------------------------
# full FLOPs / bytes walk (while-trip-count aware)
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\((.*)\)(.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ENTRY_RE = re.compile(r"^\s*ENTRY\s+%?([\w\.\-]+)", re.M)
_DIMS_RE = re.compile(r"\[([\d,]*)\]")

# ops whose operands/results live in registers after fusion — not memory
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "add-dependency",
             "domain", "partition-id", "replica-id", "iota", "fusion-marker"}


def _shape_dims(shape_str: str) -> List[int]:
    m = _DIMS_RE.search(shape_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _parse_computation(lines: List[str]):
    """-> (symbol table name->shape str, instruction tuples)."""
    symbols: Dict[str, str] = {}
    instrs = []
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, operands, attrs = m.groups()
        symbols[name] = shape
        instrs.append((name, shape, op, operands, attrs, line))
    return symbols, instrs


# lhs operand of a dot: either 'dot(%name, ...' (bare refs) or the typed
# form current XLA prints, 'dot(f32[128,256]{1,0} %name, ...' — capture the
# optional inline shape and the name
_DOT_LHS_RE = re.compile(
    r"dot\(\s*(?:(\w+\[[\d,]*\](?:\{[\d,]*\})?)\s+)?%?([\w\.\-]+)")


def _dot_flops(shape: str, line: str, symbols: Dict[str, str]) -> float:
    """2 * result_elems * prod(lhs contracting dims)."""
    res_elems = 1
    for d in _shape_dims(shape):
        res_elems *= d
    mo = _DOT_LHS_RE.search(line)
    if not mo:
        return 0.0
    # inline operand shape (typed operands) beats the symbol table; with
    # bare refs the shape comes from the producing instruction
    lhs_shape = mo.group(1) or symbols.get(mo.group(2), "")
    lhs_dims = _shape_dims(lhs_shape)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * res_elems * k


def _conv_flops(shape: str, operands: str, symbols: Dict[str, str]) -> float:
    """2 * result_elems * kernel_elems / out_features (approximation)."""
    res_elems = 1
    for d in _shape_dims(shape):
        res_elems *= d
    ops = _OPERAND_RE.findall(operands)
    if len(ops) < 2:
        return 0.0
    k_dims = _shape_dims(symbols.get(ops[1], ""))
    k_elems = 1
    for d in k_dims:
        k_elems *= d
    out_feat = k_dims[-1] if k_dims else 1
    return 2.0 * res_elems * k_elems / max(out_feat, 1)


def _operand_names(operands: str) -> List[str]:
    # operand list ends at the first ')' — attrs follow
    head = operands.split(")", 1)[0]
    return _OPERAND_RE.findall(head)


_DTYPE_RE = re.compile(r"(\w+)\[")


def _elem_width(shape_str: str) -> int:
    m = _DTYPE_RE.search(shape_str)
    return _DTYPE_BYTES.get(m.group(1), 0) if m else 0


def _roundtrip_factor(called) -> float:
    """XLA:CPU simulates bf16 compute by f32 round-trips: the fused
    computation contains ``convert(narrow)`` followed by ``convert`` back to
    the root's wide dtype (often mixed with slice/bitcast/copy ops, e.g.
    scan-layer weight fetch: dynamic-slice -> bf16 -> f32 -> bitcast). On
    TPU the value stays at the narrow width, so tensors produced by such
    fusions are counted at their SEMANTIC width (factor = narrow/wide)."""
    if called is None:
        return 1.0
    _, cinstrs = called
    if not cinstrs:
        return 1.0
    root_w = 0
    conv_widths = []
    for (n, sh, op, opr, at, line) in cinstrs:
        if line.lstrip().startswith("ROOT"):
            root_w = _elem_width(sh)
        if op == "convert":
            conv_widths.append(_elem_width(sh))
    if not root_w or not conv_widths:
        return 1.0
    narrow = min(conv_widths)
    # a true round-trip: something was narrowed below the root width AND
    # converted back up to it inside the same fusion
    if 0 < narrow < root_w and any(w == root_w for w in conv_widths):
        return narrow / root_w
    return 1.0


def _semantic_factors(parsed) -> Dict[str, float]:
    """instruction name -> semantic width factor, per convert-roundtrip
    fusion anywhere in the module (instruction names are module-unique)."""
    factors: Dict[str, float] = {}
    for name, (symbols, instrs) in parsed.items():
        for (iname, shape, op, operands, attrs, line) in instrs:
            if op != "fusion":
                continue
            mm = re.search(r"calls=%?([\w\.\-]+)", line)
            if not mm:
                continue
            f = _roundtrip_factor(parsed.get(mm.group(1)))
            if f < 1.0:
                factors[iname] = f
    return factors


def _instr_bytes(shape: str, operands: str, symbols: Dict[str, str],
                 factors: Optional[Dict[str, float]] = None,
                 own: str = "") -> float:
    factors = factors or {}
    b = _shape_bytes(shape) * factors.get(own, 1.0)
    for o in _operand_names(operands):
        b += _shape_bytes(symbols.get(o, "")) * factors.get(o, 1.0)
    return float(b)


def _fusion_bytes(shape: str, operands: str, symbols: Dict[str, str],
                  called: Optional[Tuple[Dict[str, str], list]],
                  factors: Optional[Dict[str, float]] = None,
                  own: str = "") -> float:
    """Bytes accessed at a fusion boundary.

    Scan-over-layers fusions take full stacked arrays but only touch a
    dynamic-slice per iteration; counting the full operand would overstate
    the loop's traffic by the trip count. Parameters consumed exclusively by
    dynamic-slice count their slice bytes; parameters consumed exclusively
    as the target of dynamic-update-slice count the update bytes (in-place
    write); a DUS root likewise counts the update, not the full buffer."""
    factors = factors or {}
    if called is None:
        return _instr_bytes(shape, operands, symbols, factors, own)
    csyms, cinstrs = called
    onames = _operand_names(operands)
    # map parameter name -> index, and find each parameter's consumers
    params = {}
    consumers = defaultdict(list)
    root_op = None
    for (n, sh, op, opr, at, line) in cinstrs:
        if op == "parameter":
            mi = re.search(r"parameter\((\d+)\)", line)
            params[n] = (sh, int(mi.group(1)) if mi else -1)
        for o in _operand_names(opr):
            consumers[o].append((op, sh, opr))
        if line.lstrip().startswith("ROOT") or " ROOT " in line:
            root_op = (op, sh, opr)

    total = 0.0
    for pname, (pshape, pidx) in params.items():
        oname = onames[pidx] if 0 <= pidx < len(onames) else ""
        f = factors.get(oname, 1.0)
        cons = consumers.get(pname, [])
        if cons and all(c[0] in ("dynamic-slice", "slice") for c in cons):
            total += f * sum(_shape_bytes(c[1]) for c in cons)
        elif cons and all(
                c[0] == "dynamic-update-slice"
                and _operand_names(c[2])[:1] == [pname] for c in cons):
            # in-place update target: read/write only the update window
            for c in cons:
                upd = _operand_names(c[2])
                if len(upd) > 1:
                    total += f * _shape_bytes(csyms.get(upd[1], ""))
        else:
            total += f * _shape_bytes(pshape)
    f_own = factors.get(own, 1.0)
    # result bytes: a DUS root writes only the update window
    if root_op and root_op[0] == "dynamic-update-slice":
        upd = _operand_names(root_op[2])
        total += f_own * (_shape_bytes(csyms.get(upd[1], ""))
                          if len(upd) > 1 else _shape_bytes(shape))
    else:
        total += f_own * _shape_bytes(shape)
    return total


def hlo_cost(hlo: str) -> Dict[str, float]:
    """{"flops", "bytes", "dot_flops", "instr_count"} from a post-SPMD HLO
    module text, with while-loop bodies multiplied by their trip counts.

    Semantics match XLA's per-instruction cost analysis on post-fusion HLO:
    every instruction reads its operands and writes its result to memory;
    fusion internals are free (flops inside fusions ARE counted)."""
    comps = _split_computations(hlo)
    trips = _while_bodies_with_trips(hlo, comps)
    parsed = {name: _parse_computation(lines) for name, lines in comps.items()}
    factors = _semantic_factors(parsed)
    # propagate semantic width through shape-preserving ops (collectives,
    # copies): a collective of a roundtrip-fusion output is narrow too
    for _ in range(2):
        for name, (symbols, instrs) in parsed.items():
            for (iname, shape, op, operands, attrs, line) in instrs:
                if iname in factors:
                    continue
                if op in ("copy", "bitcast", "reshape", "transpose") or \
                        any(op.startswith(c) for c in COLLECTIVES):
                    ons = _operand_names(operands)
                    if ons and all(o in factors for o in ons):
                        factors[iname] = factors[ons[0]]
    em = _ENTRY_RE.search(hlo)
    entry = em.group(1) if em else next(iter(comps), None)

    # map while-op line -> (cond, body) for per-callsite trip attribution
    def cost_of(name: str, depth: int = 0) -> Tuple[float, float]:
        if name not in parsed or depth > 12:
            return (0.0, 0.0)
        symbols, instrs = parsed[name]
        flops = 0.0
        bytes_ = 0.0
        for iname, shape, op, operands, attrs, line in instrs:
            if op == "dot":
                flops += _dot_flops(shape, line, symbols)
                bytes_ += _instr_bytes(shape, operands, symbols, factors, iname)
            elif op == "convolution":
                flops += _conv_flops(shape, operands, symbols)
                bytes_ += _instr_bytes(shape, operands, symbols, factors, iname)
            elif op == "while":
                mm = re.search(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)",
                               line)
                if mm:
                    cond, body = mm.groups()
                    t = trips.get(body, 1)
                    f_b, b_b = cost_of(body, depth + 1)
                    f_c, b_c = cost_of(cond, depth + 1)
                    flops += t * (f_b + f_c)
                    bytes_ += t * (b_b + b_c)
            elif op == "conditional":
                for bc in re.findall(r"branch_computations=\{([^}]*)\}", line):
                    branch_costs = [cost_of(b.strip().lstrip("%"), depth + 1)
                                    for b in bc.split(",")]
                    if branch_costs:
                        flops += max(c[0] for c in branch_costs)
                        bytes_ += max(c[1] for c in branch_costs)
            elif op == "call":
                mm = re.search(r"to_apply=%?([\w\.\-]+)", line)
                if mm:
                    f_c, b_c = cost_of(mm.group(1), depth + 1)
                    flops += f_c
                    bytes_ += b_c
                bytes_ += _instr_bytes(shape, operands, symbols, factors, iname)
            elif op == "fusion":
                # internals are on-chip; count dot flops inside, bytes at
                # the fusion boundary only (slice-aware for scan patterns)
                mm = re.search(r"calls=%?([\w\.\-]+)", line)
                called = parsed.get(mm.group(1)) if mm else None
                if mm:
                    f_c, _ = cost_of(mm.group(1), depth + 1)
                    flops += f_c
                bytes_ += _fusion_bytes(shape, operands, symbols, called,
                                        factors, iname)
            elif op in ("slice", "dynamic-slice"):
                # reads only the window it produces
                bytes_ += 2.0 * _shape_bytes(shape)
            elif op == "dynamic-update-slice":
                # in-place window write: read + write the update only
                upd = _operand_names(operands)
                ub = (_shape_bytes(symbols.get(upd[1], ""))
                      if len(upd) > 1 else _shape_bytes(shape))
                bytes_ += 2.0 * ub
            elif op in _NO_BYTES:
                continue
            else:
                bytes_ += _instr_bytes(shape, operands, symbols, factors, iname)
        return flops, bytes_

    # memoize via simple cache keyed by name (trip-independent)
    cache: Dict[str, Tuple[float, float]] = {}
    orig = cost_of

    def cost_cached(name: str, depth: int = 0) -> Tuple[float, float]:
        if name in cache:
            return cache[name]
        r = orig(name, depth)
        cache[name] = r
        return r

    cost_of = cost_cached  # noqa: F811 — recursion goes through the cache
    flops, bytes_ = cost_of(entry) if entry else (0.0, 0.0)
    return {"flops": flops, "bytes": bytes_}


def top_bytes(hlo: str, n: int = 20):
    """The heaviest instructions by bytes x loop-trips — the §Perf profile
    (what to look at first when the memory roofline term dominates)."""
    comps = _split_computations(hlo)
    trips = _while_bodies_with_trips(hlo, comps)
    calls = _called_by(comps)
    parsed = {name: _parse_computation(lines) for name, lines in comps.items()}
    factors = _semantic_factors(parsed)
    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    for body, t in trips.items():
        stack = [(body, float(t))]
        seen = set()
        while stack:
            nm, m = stack.pop()
            if nm in seen:
                continue
            seen.add(nm)
            mult[nm] = max(mult[nm], m)
            for ch in calls.get(nm, []):
                stack.append((ch, m * trips.get(ch, 1)))
    rows = []
    for name, (symbols, instrs) in parsed.items():
        m = mult[name]
        for (iname, shape, op, operands, attrs, line) in instrs:
            if op in _NO_BYTES or op in ("while",):
                continue
            if op == "fusion":
                mm = re.search(r"calls=%?([\w\.\-]+)", line)
                b = _fusion_bytes(shape, operands, symbols,
                                  parsed.get(mm.group(1)) if mm else None,
                                  factors, iname)
            elif op in ("slice", "dynamic-slice"):
                b = 2.0 * _shape_bytes(shape)
            else:
                b = _instr_bytes(shape, operands, symbols, factors, iname)
            rows.append((b * m, op, shape.split("{")[0][:60], m, name[:40]))
    rows.sort(reverse=True)
    return rows[:n]
