"""Crash-safe artifact I/O.

Every JSON artifact the simulator emits (sweep cache files,
``analysis.report.save_json`` payloads, ``BENCH_engine.json``,
``results/bench/*.json``, Perfetto traces) used to be written with a bare
``open(path, "w")`` — a process killed mid-write (sweep worker OOM, CI
timeout, ctrl-C) leaves a torn file that poisons the next run.  The
helpers here write to a temporary file *in the same directory* (same
filesystem, so the final rename is atomic) and ``os.replace`` it over the
destination: readers observe either the old complete file or the new
complete file, never a prefix.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Callable, Optional


def atomic_write_text(path: str, text: str) -> None:
    """Write ``text`` to ``path`` atomically (tempfile + ``os.replace``)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: str, obj: Any, *, indent: Optional[int] = 1,
                      default: Optional[Callable] = None,
                      separators=None) -> None:
    """Serialize ``obj`` as JSON and write it atomically.

    Serialization happens *before* the file exists, so a ``TypeError`` from
    an unserializable object cannot leave a truncated artifact behind."""
    text = json.dumps(obj, indent=indent, default=default,
                      separators=separators)
    atomic_write_text(path, text)
