"""Parallel what-if sweep driver: workloads x machines x knob grids.

One cycle simulation is paid per (workload, machine) point — recorded with
events — then every knob point is answered by DAG replay, which is orders of
magnitude cheaper than re-simulation (the ROADMAP "speed" axis: replay
instead of resimulate).  (workload, machine) points fan out over per-point
worker processes, and finished points are cached as JSON keyed by a hash of
the full configuration, so an interrupted or extended sweep only pays for
new points.

Crash-proofing (docs/robustness.md):

  * **per-point workers** — every point runs in its own ``mp.Process`` with
    a pipe back to the parent, so one crashing / OOM-killed / hanging point
    cannot take down the rest of the sweep (the old shared ``mp.Pool``
    died wholesale);
  * **timeouts + retry with exponential backoff** — a point that exceeds
    ``timeout_s`` is terminated and retried (``retries`` times, waiting
    ``backoff_s * 2**attempt`` between attempts); a point that exhausts its
    retries raises :class:`SweepError` *after* every completed point has
    already been flushed;
  * **incremental atomic cache flush** — each point's rows are written to
    its cache file the moment the point completes (temp file + ``os.replace``
    via ``repro.utils.ioutil``), not at sweep end, so a killed sweep loses
    at most in-flight points;
  * **corrupt-cache quarantine** — a truncated/invalid cache file is moved
    aside to ``<name>.corrupt`` and the point recomputed, instead of the
    whole sweep dying on ``json.JSONDecodeError``.

Hierarchical-fidelity points record the first-wave engine; the replay ratio
(predicted / measured wave makespan) is applied to the composed total, which
keeps the wave-composition arithmetic of ``simulate_fa3`` intact.

Cache files carry an ``obs.manifest`` provenance stamp (``{"manifest": ...,
"rows": [...]}``); the hash key deliberately covers only the *configuration*
(workload, machine, fidelity, kernel, knob grid), not the code version — a
stale cache written by older simulator code is still served, but the
manifest's git sha makes that auditable (see docs/analysis.md).  Bare-list
cache files from before the stamp are still read.
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import os
import time
from dataclasses import asdict, dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.whatif import Knobs
from repro.utils.ioutil import atomic_write_json


class SweepError(RuntimeError):
    """A sweep point failed permanently (all retries exhausted).  Every
    *other* completed point has already been flushed to the cache, so the
    re-run only pays for the failed point."""


@dataclass(frozen=True)
class SweepPoint:
    """One (workload, machine) cell of the sweep, before knob expansion."""
    workload: object            # AttnWorkload (frozen dataclass, picklable)
    machine: object             # GPUMachine (frozen dataclass, picklable)
    fidelity: str = "auto"
    n_sub: int = 8
    kernel: str = "fa3"         # registered kernel program name
    mem_fidelity: Optional[str] = None  # engine memory model override
                                        # (None = let fidelity decide)


def _key(point: SweepPoint, grid: Sequence[Knobs]) -> str:
    blob = json.dumps([asdict(point.workload), asdict(point.machine),
                       point.fidelity, point.n_sub, point.kernel,
                       point.mem_fidelity,
                       [asdict(k) for k in grid]], sort_keys=True)
    return hashlib.md5(blob.encode()).hexdigest()[:16]


def _sweep_one(args) -> List[Dict]:
    """Worker: one cycle simulation + a full knob-grid replay."""
    point, grid = args
    from repro.analysis import dag as dag_mod
    from repro.analysis import whatif
    from repro.core.simfa import simulate_fa3

    t0 = time.perf_counter()
    eopts = {"mem_fidelity": point.mem_fidelity} if point.mem_fidelity else None
    base = simulate_fa3(point.workload, point.machine, fidelity=point.fidelity,
                        n_sub=point.n_sub, record_events=True,
                        kernel=point.kernel, engine_opts=eopts)
    sim_s = time.perf_counter() - t0
    dag = dag_mod.build(base.trace.events, base.trace.dispatch_parent)
    rows = []
    for knobs in grid:
        r = whatif.replay(dag, knobs)
        ratio = r.makespan / max(dag.makespan, 1)
        pred_cycles = base.cycles * ratio
        rows.append({
            "workload": point.workload.name,
            "machine": point.machine.name,
            "kernel": point.kernel,
            "fidelity": base.fidelity,
            "mem_fidelity": base.mem_fidelity,
            "knobs": asdict(knobs),
            "knobs_label": knobs.label(),
            "base_cycles": base.cycles,
            "base_us": base.latency_us,
            "pred_cycles": pred_cycles,
            "pred_us": pred_cycles / (point.machine.freq_ghz * 1e3),
            "speedup": base.cycles / max(pred_cycles, 1e-9),
            "sim_s": sim_s,
            "replay_s": r.replay_s,
        })
    return rows


# ---------------------------------------------------------------------------
# cache I/O (atomic writes, quarantined reads)
# ---------------------------------------------------------------------------

def _cache_path(cache_dir: str, point: SweepPoint,
                grid: Sequence[Knobs]) -> str:
    return os.path.join(cache_dir, f"whatif_{_key(point, grid)}.json")


def _load_cache(path: str) -> Optional[List[Dict]]:
    """Read one cache file; quarantine and miss on any corruption.

    A torn write (pre-atomic-write artifacts), a truncated disk, or a
    schema from some future refactor must cost one recompute, never the
    sweep: the bad file is renamed to ``<path>.corrupt`` (atomic, same
    directory) so it stays inspectable without being re-read forever."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
        # stamped format is {"manifest": ..., "rows": [...]};
        # pre-manifest caches were bare row lists
        rows = payload["rows"] if isinstance(payload, dict) else payload
        if not isinstance(rows, list):
            raise KeyError("rows")
        return rows
    except (json.JSONDecodeError, KeyError, UnicodeDecodeError, OSError):
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        return None


def _flush_point(cache_dir: str, point: SweepPoint, grid: Sequence[Knobs],
                 rows: List[Dict]) -> None:
    from repro.obs.manifest import build_manifest
    os.makedirs(cache_dir, exist_ok=True)
    manifest = build_manifest(
        machine=point.machine, workload=point.workload,
        kernel=point.kernel, fidelity=point.fidelity,
        mem_fidelity=(rows[0].get("mem_fidelity") if rows
                      else point.mem_fidelity),
        extra={"grid_points": len(grid)})
    atomic_write_json(_cache_path(cache_dir, point, grid),
                      {"manifest": manifest, "rows": rows})


# ---------------------------------------------------------------------------
# per-point worker processes
# ---------------------------------------------------------------------------

def _point_main(conn, worker: Callable, args) -> None:
    """Child entry: run one point, ship ("ok", rows) or ("err", msg) back.
    Any uncaught explosion (or a kill -9) simply leaves the pipe without a
    result — the parent treats both identically as a crashed attempt."""
    try:
        rows = worker(args)
    except BaseException as e:          # noqa: BLE001 — crash isolation
        try:
            conn.send(("err", f"{type(e).__name__}: {e}"))
        except Exception:
            pass
        os._exit(1)
    try:
        conn.send(("ok", rows))
        conn.close()
    except Exception:
        os._exit(1)
    os._exit(0)


def run_sweep(points: Sequence[SweepPoint], grid: Sequence[Knobs], *,
              processes: Optional[int] = None,
              cache_dir: Optional[str] = None,
              timeout_s: Optional[float] = None,
              retries: int = 2,
              backoff_s: float = 0.5,
              worker: Optional[Callable] = None) -> List[Dict]:
    """Run the sweep; ``processes<=1`` runs serially (tests, small sweeps).

    With ``cache_dir`` set, each (workload, machine, grid) cell is read
    from / written to ``<cache_dir>/<hash>.json`` — incrementally (each
    point flushes on completion) and atomically (temp file + rename), with
    corrupted cache files quarantined to ``<name>.corrupt`` and recomputed.

    ``timeout_s`` bounds each point's wall time (parallel mode; the child
    is terminated on expiry).  Crashed or timed-out points are retried up
    to ``retries`` extra times with exponential backoff (``backoff_s *
    2**attempt``); a point failing every attempt raises :class:`SweepError`
    after all other points finished and flushed.  ``worker`` overrides the
    per-point function (tests inject crashy/fast workers); it must accept
    ``(point, grid)`` and return a row list."""
    grid = list(grid)
    worker = worker or _sweep_one
    results: List[Optional[List[Dict]]] = [None] * len(points)
    todo: List[int] = []
    for i, point in enumerate(points):
        if cache_dir:
            cached = _load_cache(_cache_path(cache_dir, point, grid))
            if cached is not None:
                results[i] = cached
                continue
        todo.append(i)

    if todo:
        if processes is None:
            processes = min(len(todo), os.cpu_count() or 1)
        # serial only when explicitly requested (processes<=1): a lone todo
        # point under processes>1 still gets a worker process, because the
        # process boundary is what timeout kill / crash isolation hang on
        if processes <= 1:
            _run_serial(points, grid, todo, results, cache_dir, worker,
                        retries, backoff_s)
        else:
            _run_parallel(points, grid, todo, results, cache_dir, worker,
                          processes, timeout_s, retries, backoff_s)

    return [row for rows in results for row in rows]


def _run_serial(points, grid, todo, results, cache_dir, worker,
                retries, backoff_s) -> None:
    for i in todo:
        last = None
        for attempt in range(retries + 1):
            if attempt:
                time.sleep(backoff_s * 2 ** (attempt - 1))
            try:
                rows = worker((points[i], grid))
                break
            except Exception as e:      # in-process retry (no isolation)
                last = e
        else:
            raise SweepError(
                f"sweep point {i} ({points[i].workload.name} on "
                f"{points[i].machine.name}) failed after {retries + 1} "
                f"attempts: {last}") from last
        results[i] = rows
        if cache_dir:
            _flush_point(cache_dir, points[i], grid, rows)


def _run_parallel(points, grid, todo, results, cache_dir, worker,
                  processes, timeout_s, retries, backoff_s) -> None:
    """Per-point process scheduler with kill-on-timeout and backoff retry.

    ``waiting`` holds ``(index, attempt, not_before)`` triples (backoff is
    enforced by the ``not_before`` wall-clock stamp, without blocking other
    points); ``running`` maps index -> live child.  A child that dies
    without delivering rows — crash, ``os._exit``, kill — counts exactly
    like a timeout: terminate (if needed), back off, retry."""
    ctx = mp.get_context()
    waiting: List[Tuple[int, int, float]] = [(i, 0, 0.0) for i in todo]
    running: Dict[int, Tuple] = {}      # idx -> (proc, conn, attempt, t0)
    failures: List[str] = []

    def _reap(idx: int, ok: bool, payload) -> None:
        proc, conn, attempt, _t0 = running.pop(idx)
        conn.close()
        if ok:
            results[idx] = payload
            if cache_dir:
                _flush_point(cache_dir, points[idx], grid, payload)
            return
        if attempt < retries:
            delay = backoff_s * 2 ** attempt
            waiting.append((idx, attempt + 1, time.monotonic() + delay))
        else:
            failures.append(
                f"sweep point {idx} ({points[idx].workload.name} on "
                f"{points[idx].machine.name}) failed after "
                f"{retries + 1} attempts: {payload}")

    while waiting or running:
        now = time.monotonic()
        # launch due points into free slots
        ready = [w for w in waiting if w[2] <= now]
        for w in sorted(ready, key=lambda t: t[0]):
            if len(running) >= processes:
                break
            waiting.remove(w)
            idx, attempt, _ = w
            parent, child = ctx.Pipe(duplex=False)
            proc = ctx.Process(target=_point_main,
                               args=(child, worker, (points[idx], grid)),
                               daemon=True)
            proc.start()
            child.close()
            running[idx] = (proc, parent, attempt, now)
        # collect finished / crashed / overdue children
        progressed = False
        for idx in list(running):
            proc, conn, attempt, t0 = running[idx]
            if conn.poll():
                try:
                    status, payload = conn.recv()
                except (EOFError, OSError):
                    # EOF with no message: the child died (crash / exit /
                    # kill) before delivering rows
                    status, payload = "err", "worker died without delivering rows"
                proc.join(timeout=5)
                if proc.is_alive():
                    proc.terminate()
                    proc.join()
                _reap(idx, status == "ok", payload)
                progressed = True
            elif not proc.is_alive():
                proc.join()
                _reap(idx, False,
                      f"worker died (exit code {proc.exitcode}) before "
                      f"delivering rows")
                progressed = True
            elif timeout_s is not None and time.monotonic() - t0 > timeout_s:
                proc.terminate()
                proc.join()
                _reap(idx, False, f"timed out after {timeout_s} s")
                progressed = True
        if not progressed and (running or waiting):
            time.sleep(0.02)

    if failures:
        raise SweepError("; ".join(failures))


def knob_grid(tma_bw=(1.0,), wgmma=(1.0,), softmax=(1.0,)) -> List[Knobs]:
    """Cartesian grid over per-resource multipliers."""
    return [Knobs(tma_bw=t, wgmma=w, softmax=s)
            for t in tma_bw for w in wgmma for s in softmax]
