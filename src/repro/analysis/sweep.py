"""Parallel what-if sweep driver: workloads x machines x knob grids.

One cycle simulation is paid per (workload, machine) point — recorded with
events — then every knob point is answered by DAG replay, which is orders of
magnitude cheaper than re-simulation (the ROADMAP "speed" axis: replay
instead of resimulate).  (workload, machine) points fan out over a
``multiprocessing`` pool, and finished points are cached as JSON keyed by a
hash of the full configuration, so an interrupted or extended sweep only
pays for new points.

Hierarchical-fidelity points record the first-wave engine; the replay ratio
(predicted / measured wave makespan) is applied to the composed total, which
keeps the wave-composition arithmetic of ``simulate_fa3`` intact.

Cache files carry an ``obs.manifest`` provenance stamp (``{"manifest": ...,
"rows": [...]}``); the hash key deliberately covers only the *configuration*
(workload, machine, fidelity, kernel, knob grid), not the code version — a
stale cache written by older simulator code is still served, but the
manifest's git sha makes that auditable (see docs/analysis.md).  Bare-list
cache files from before the stamp are still read.
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import os
import time
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence

from repro.analysis.whatif import Knobs


@dataclass(frozen=True)
class SweepPoint:
    """One (workload, machine) cell of the sweep, before knob expansion."""
    workload: object            # AttnWorkload (frozen dataclass, picklable)
    machine: object             # GPUMachine (frozen dataclass, picklable)
    fidelity: str = "auto"
    n_sub: int = 8
    kernel: str = "fa3"         # registered kernel program name


def _key(point: SweepPoint, grid: Sequence[Knobs]) -> str:
    blob = json.dumps([asdict(point.workload), asdict(point.machine),
                       point.fidelity, point.n_sub, point.kernel,
                       [asdict(k) for k in grid]], sort_keys=True)
    return hashlib.md5(blob.encode()).hexdigest()[:16]


def _sweep_one(args) -> List[Dict]:
    """Worker: one cycle simulation + a full knob-grid replay."""
    point, grid = args
    from repro.analysis import dag as dag_mod
    from repro.analysis import whatif
    from repro.core.simfa import simulate_fa3

    t0 = time.perf_counter()
    base = simulate_fa3(point.workload, point.machine, fidelity=point.fidelity,
                        n_sub=point.n_sub, record_events=True,
                        kernel=point.kernel)
    sim_s = time.perf_counter() - t0
    dag = dag_mod.build(base.trace.events, base.trace.dispatch_parent)
    rows = []
    for knobs in grid:
        r = whatif.replay(dag, knobs)
        ratio = r.makespan / max(dag.makespan, 1)
        pred_cycles = base.cycles * ratio
        rows.append({
            "workload": point.workload.name,
            "machine": point.machine.name,
            "kernel": point.kernel,
            "fidelity": base.fidelity,
            "knobs": asdict(knobs),
            "knobs_label": knobs.label(),
            "base_cycles": base.cycles,
            "base_us": base.latency_us,
            "pred_cycles": pred_cycles,
            "pred_us": pred_cycles / (point.machine.freq_ghz * 1e3),
            "speedup": base.cycles / max(pred_cycles, 1e-9),
            "sim_s": sim_s,
            "replay_s": r.replay_s,
        })
    return rows


def run_sweep(points: Sequence[SweepPoint], grid: Sequence[Knobs], *,
              processes: Optional[int] = None,
              cache_dir: Optional[str] = None) -> List[Dict]:
    """Run the sweep; ``processes<=1`` runs serially (tests, small sweeps).

    With ``cache_dir`` set, each (workload, machine, grid) cell is read from
    / written to ``<cache_dir>/<hash>.json``.
    """
    grid = list(grid)
    results: List[Optional[List[Dict]]] = [None] * len(points)
    todo = []
    for i, point in enumerate(points):
        if cache_dir:
            path = os.path.join(cache_dir, f"whatif_{_key(point, grid)}.json")
            if os.path.exists(path):
                with open(path) as f:
                    payload = json.load(f)
                # stamped format is {"manifest": ..., "rows": [...]};
                # pre-manifest caches were bare row lists
                results[i] = payload["rows"] if isinstance(payload, dict) \
                    else payload
                continue
        todo.append(i)

    if todo:
        args = [(points[i], grid) for i in todo]
        if processes is None:
            processes = min(len(todo), os.cpu_count() or 1)
        if processes <= 1 or len(todo) == 1:
            fresh = [_sweep_one(a) for a in args]
        else:
            with mp.Pool(processes) as pool:
                fresh = pool.map(_sweep_one, args)
        for i, rows in zip(todo, fresh):
            results[i] = rows
            if cache_dir:
                from repro.obs.manifest import build_manifest
                os.makedirs(cache_dir, exist_ok=True)
                path = os.path.join(cache_dir,
                                    f"whatif_{_key(points[i], grid)}.json")
                point = points[i]
                manifest = build_manifest(
                    machine=point.machine, workload=point.workload,
                    kernel=point.kernel, fidelity=point.fidelity,
                    extra={"grid_points": len(grid)})
                with open(path, "w") as f:
                    json.dump({"manifest": manifest, "rows": rows},
                              f, indent=1)

    return [row for rows in results for row in rows]


def knob_grid(tma_bw=(1.0,), wgmma=(1.0,), softmax=(1.0,)) -> List[Knobs]:
    """Cartesian grid over per-resource multipliers."""
    return [Knobs(tma_bw=t, wgmma=w, softmax=s)
            for t in tma_bw for w in wgmma for s in softmax]
