"""Typed pipeline events with causal metadata (the trace the DAG is built on).

The cycle engine used to emit flat ``(tag, start, end)`` gantt tuples — enough
to draw Fig. 7, useless for asking *why* a warpgroup stalled.  A
:class:`PipeEvent` instead records, for every executed instruction and every
async engine operation, the operands and *ordinal* information needed to
reconstruct the causal edges afterwards:

  * an mbarrier wait records which signal count it required (``dep_n``), and
    every TMA load records which signal ordinal it produced — matching the two
    gives the exact ``signal -> wait`` edge;
  * ``producer_acquire`` records the release ordinal it blocked on,
    ``consumer_release`` its own ordinal;
  * WGMMA/TMA drain waits record the highest group id that had to complete;
  * async engine events (``mma``, ``tma``) record the lane event that issued
    them (``src``) so issue->execute edges are explicit.

Event kinds
  ``issue``  — one instruction leaving the warpgroup's instruction stream;
               occupies the lane for zero cycles (``t0 == t1``).
  ``bubble`` — a CUDA-core block (softmax etc.); occupies ``[t0, t1)``.
  ``mma``    — one WGMMA executing on the SM tensor-core pipeline.
  ``tma``    — one TMA load/store job (submit at ``t0``, last line at ``t1``;
               ``fixed`` = descriptor/launch setup cycles, the non-bandwidth
               portion a what-if must not scale).

``t_done`` is when the event's *effect* lands (mbarrier signal time, WGMMA
group completion, ...); for synchronous lane events ``t_done == t1``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import isa

# event kinds
ISSUE, BUBBLE, MMA, TMA = "issue", "bubble", "mma", "tma"

# ops carried by engine-side events
TMA_LOAD_JOB = "TMA_LOAD_JOB"
TMA_STORE_JOB = "TMA_STORE_JOB"
WGMMA_EXEC = "WGMMA_EXEC"


@dataclass
class PipeEvent:
    eid: int
    kind: str                  # issue | bubble | mma | tma
    op: str                    # isa opcode or engine-op constant above
    sm: int
    cta: int                   # global CTA launch index
    wg: int                    # warpgroup id within the CTA
    label: str                 # "cta{idx}/{role}", e.g. "cta0/consumer1"
                               # ("cta{idx}/wg{id}" for role-less traces)
    tag: str = ""
    t0: int = 0                # start (issue cycle / engine start)
    t1: int = 0                # end of lane/engine occupancy
    t_done: int = 0            # effect completion time
    sid: int = -1
    gid: int = -1
    bid: int = -1
    dep_n: int = 0             # wait: required ordinal; signal: own ordinal
    fixed: int = 0             # non-scalable cycles (TMA setup)
    src: int = -1              # issuing lane event (engine events only)

    @property
    def dur(self) -> int:
        return self.t1 - self.t0


class EventTracer:
    """Engine hook sink: builds the :class:`PipeEvent` list during a run.

    The tracer is deliberately dumb — it snapshots counters at well-defined
    points (before ``_apply_blocking``/``_execute`` mutate them for lane
    events, after the mbarrier increment for TMA completions) and leaves all
    graph construction to :mod:`repro.analysis.dag`.  Event ids are a valid
    topological order of the eventual DAG: every event is created after all
    of its predecessors.

    Contract with the engine's waiter-indexed scheduler: parking/waking
    threads must never change *when* an instruction issues, only how the
    engine finds it — so the ordinal snapshots here (``dep_n``, signal
    counts) stay bit-identical between the waiter and broadcast schedulers
    (enforced by ``tests/test_engine_equiv.py``).  Within one cycle, events
    from different SMs are ordered by ascending SM id — the engine pins
    that order deterministically; it is the one place the trace may differ
    from pre-PR-4 runs, which inherited CPython set-iteration order.
    """

    def __init__(self):
        self.events: List[PipeEvent] = []
        # child cta idx -> parent cta idx whose retirement freed the slot
        self.dispatch_parent: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def _new(self, **kw) -> PipeEvent:
        ev = PipeEvent(eid=len(self.events), **kw)
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------------
    def on_issue(self, cycle: int, th, ins) -> int:
        """One instruction issued by warpgroup thread ``th``.

        Must run *before* the engine's ``_apply_blocking``/``_execute`` so the
        counter snapshots below still reflect the pre-issue state.
        """
        cta = th.cta
        op = ins.op
        kind = ISSUE
        t1 = t_done = cycle
        dep_n = 0
        if op == isa.MB_WAIT:
            dep_n = th.mb_expected.get(ins.sid, 0) + 1       # signal we needed
        elif op == isa.ACQUIRE_STAGE:
            use = th.acq_count.get(ins.sid, 0)
            dep_n = use * cta.n_consumers                    # release ordinal
        elif op == isa.RELEASE_STAGE:
            dep_n = cta.stage_releases.get(ins.sid, 0) + 1   # own ordinal
        elif op == isa.BAR_ARRIVE:
            dep_n = cta.bar_arrivals.get(ins.bid, 0) + 1     # own ordinal
        elif op == isa.BAR_WAIT:
            dep_n = ins.n                                    # arrival ordinal
        elif op in (isa.WGMMA_WAIT, isa.TMA_WAIT):
            dep_n = ins.gid - ins.n                          # drain threshold
        elif op == isa.BUBBLES:
            kind = BUBBLE
            t1 = t_done = cycle + ins.cycles
        ev = self._new(kind=kind, op=op, sm=th.sm.sm_id, cta=cta.idx,
                       wg=th.wg_id, label=th.label, tag=ins.tag, t0=cycle,
                       t1=t1, t_done=t_done, sid=ins.sid, gid=ins.gid,
                       bid=ins.bid, dep_n=dep_n)
        return ev.eid

    def on_mma(self, src_eid: int, th, ins, start: int, end: int) -> int:
        ev = self._new(kind=MMA, op=WGMMA_EXEC, sm=th.sm.sm_id,
                       cta=th.cta.idx, wg=th.wg_id, label=th.label,
                       tag=ins.tag, t0=start, t1=end, t_done=end,
                       gid=ins.gid, src=src_eid)
        return ev.eid

    def on_tma(self, src_eid: int, th, *, write: bool, tag: str, t0: int,
               t1: int, fixed: int, sid: int = -1, gid: int = -1,
               signal_n: int = 0) -> int:
        """One finished TMA job.  For loads ``signal_n`` is the mbarrier
        signal ordinal this completion produced on ``(cta, sid)``."""
        ev = self._new(kind=TMA, op=TMA_STORE_JOB if write else TMA_LOAD_JOB,
                       sm=th.sm.sm_id, cta=th.cta.idx, wg=th.wg_id,
                       label=th.label, tag=tag, t0=t0, t1=t1, t_done=t1,
                       sid=sid, gid=gid, dep_n=signal_n, fixed=fixed,
                       src=src_eid)
        return ev.eid

    def on_dispatch(self, child_cta: int, parent_cta: Optional[int]):
        if parent_cta is not None:
            self.dispatch_parent[child_cta] = parent_cta

    # ------------------------------------------------------------------
    def __len__(self):
        return len(self.events)
