"""repro.analysis: dependency-DAG pipeline analysis for Sim-FA traces.

Modules (import them explicitly; only the pure event layer is re-exported
here so that ``core.engine`` can import the tracer without a cycle):

  * ``events``        — typed :class:`PipeEvent` records + the engine tracer
  * ``dag``           — dependency-DAG construction over an event trace
  * ``critical_path`` — critical path extraction + per-WG stall attribution
  * ``whatif``        — DAG replay under scaled resource costs
  * ``sweep``         — multiprocessing what-if sweep driver w/ JSON caching
  * ``report``        — text / JSON report rendering
  * ``hazards``       — runtime hazard sanitizer (``Engine(sanitize=True)``)
                        + deadlock wait-for-graph explainer
"""
from repro.analysis.events import EventTracer, PipeEvent  # noqa: F401

__all__ = ["EventTracer", "PipeEvent", "events", "dag", "critical_path",
           "whatif", "sweep", "report", "hazards"]
