"""What-if replay: predict launch latency under scaled resource costs
without re-running the cycle engine.

The DAG is replayed in topological (event-id) order: each node starts at the
latest of its predecessors' releases plus its recorded scheduler slack, and
its duration is scaled by the knob for its resource class:

  * ``tma_bw``  — scales the post-setup (streaming) portion of every TMA
                  job; the descriptor/launch setup cycles (``fixed``) are
                  latency, not bandwidth, and are left alone;
  * ``wgmma``   — scales tensor-core execution time;
  * ``softmax`` — scales CUDA-core bubble blocks (e.g. a MUFU-rich vs
                  MUFU-poor softmax variant).

With every knob at x1.0 the replay reproduces the simulated schedule
*exactly* (slack is the measured residual, so starts telescope back to the
measured starts) — that identity is the validation anchor, and re-simulation
agreement on scaled knobs is checked by ``validate_replay`` /
``benchmarks/bench_whatif.py``.

Approximations (documented, deliberate): memory-system contention inside a
TMA job's measured duration is scaled together with the streaming portion;
scheduler slack is held fixed; edge matching is the measured one (a knob
change never re-matches which signal a wait consumed).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List

from repro.analysis.dag import END, PipelineDAG
from repro.analysis.events import BUBBLE, MMA, TMA


@dataclass(frozen=True)
class Knobs:
    tma_bw: float = 1.0        # TMA streaming bandwidth multiplier
    wgmma: float = 1.0         # tensor-core throughput multiplier
    softmax: float = 1.0       # CUDA-core (bubble) throughput multiplier

    def label(self) -> str:
        return (f"tma x{self.tma_bw:g} / wgmma x{self.wgmma:g} / "
                f"softmax x{self.softmax:g}")

    def is_baseline(self) -> bool:
        return self.tma_bw == self.wgmma == self.softmax == 1.0


@dataclass
class ReplayResult:
    knobs: Knobs
    makespan: float            # predicted cycles
    baseline: int              # measured (simulated) cycles
    replay_s: float            # wall time of the replay itself

    @property
    def speedup(self) -> float:
        """Predicted kernel speedup vs the measured baseline."""
        return self.baseline / self.makespan if self.makespan else float("inf")


def replay(dag: PipelineDAG, knobs: Knobs = Knobs()) -> ReplayResult:
    t_wall = time.perf_counter()
    n = len(dag.events)
    t1 = [0.0] * n             # lane-occupancy end
    done = [0.0] * n           # effect completion
    for e in dag.events:
        ready = 0.0
        for pe, mode in dag.preds[e.eid]:
            v = t1[pe] if mode == END else done[pe]
            if v > ready:
                ready = v
        start = ready + dag.slack[e.eid]
        dur = e.t1 - e.t0
        if e.kind == BUBBLE:
            occ = dur / knobs.softmax
            t1[e.eid] = done[e.eid] = start + occ
        elif e.kind == MMA:
            t1[e.eid] = done[e.eid] = start + dur / knobs.wgmma
        elif e.kind == TMA:
            stream = max(0, dur - e.fixed)
            t1[e.eid] = done[e.eid] = start + e.fixed + stream / knobs.tma_bw
        else:                   # issue: zero occupancy
            t1[e.eid] = done[e.eid] = start
    mk = max(done) if done else 0.0
    return ReplayResult(knobs=knobs, makespan=mk, baseline=dag.makespan,
                        replay_s=time.perf_counter() - t_wall)


def replay_grid(dag: PipelineDAG, grid: List[Knobs]) -> List[ReplayResult]:
    return [replay(dag, k) for k in grid]


# ---------------------------------------------------------------------------
# validation against real re-simulation
# ---------------------------------------------------------------------------

def machine_for(cfg, knobs: Knobs):
    """The machine variant a knob point corresponds to, for re-simulation.

    ``wgmma``/``softmax`` map exactly onto machine parameters; ``tma_bw``
    maps onto the integer lines-per-cycle rate, so only integer-compatible
    factors (0.5, 2, ...) re-simulate faithfully.
    """
    kw = {}
    if knobs.wgmma != 1.0:
        kw["wgmma_n_cycles_divisor"] = cfg.wgmma_n_cycles_divisor * knobs.wgmma
    if knobs.softmax != 1.0:
        kw["mufu_ops_per_cycle"] = max(1, int(round(
            cfg.mufu_ops_per_cycle * knobs.softmax)))
        kw["fp32_ops_per_cycle"] = max(1, int(round(
            cfg.fp32_ops_per_cycle * knobs.softmax)))
        kw["fp16_ops_per_cycle"] = max(1, int(round(
            cfg.fp16_ops_per_cycle * knobs.softmax)))
    if knobs.tma_bw != 1.0:
        kw["tma_lines_per_cycle"] = max(1, int(round(
            cfg.tma_lines_per_cycle * knobs.tma_bw)))
    return replace(cfg, **kw)


def validate_replay(w, cfg, knobs: Knobs = Knobs(), *, fidelity: str = "full",
                    tiling=None, rel_tol: float = 0.01) -> Dict:
    """Replay prediction vs a real re-simulation of the same knob point.

    Returns a comparison row; with all knobs at x1.0 the prediction must
    match the baseline engine makespan to ``rel_tol`` (acceptance criterion).
    """
    from repro.analysis import dag as dag_mod
    from repro.core.simfa import simulate_fa3
    from repro.core.tracegen_fa3 import FA3Tiling

    tiling = tiling or FA3Tiling()
    base = simulate_fa3(w, cfg, tiling=tiling, fidelity=fidelity,
                        record_events=True)
    dag = dag_mod.build(base.trace.events, base.trace.dispatch_parent)
    pred = replay(dag, knobs)
    # hierarchical fidelity records only the first simulated wave; scale the
    # composed total by the replayed wave ratio (same rule as sweep._sweep_one)
    pred_cycles = base.cycles * pred.makespan / max(dag.makespan, 1)
    if knobs.is_baseline():
        resim_cycles = base.cycles
    else:
        resim = simulate_fa3(w, cfg=machine_for(cfg, knobs), tiling=tiling,
                             fidelity=fidelity)
        resim_cycles = resim.cycles
    err = abs(pred_cycles - resim_cycles) / max(resim_cycles, 1e-9)
    return {
        "workload": w.name, "knobs": knobs.label(),
        "baseline_cycles": base.cycles, "pred_cycles": pred_cycles,
        "resim_cycles": resim_cycles, "rel_err": err, "ok": err <= rel_tol,
    }
