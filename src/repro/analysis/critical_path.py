"""Critical path extraction and per-warpgroup stall attribution.

The critical path is recovered by walking back from the sink (latest
completion) through each node's *binding* predecessor — the one whose
measured release time determined the node's start (ties prefer causal
``done`` edges over program order, which is the informative choice).

Stall attribution decomposes every idle cycle on every warpgroup lane into
one of five buckets (paper §6 asks exactly these questions of Fig. 7):

  ``tma-wait``       — blocked on an mbarrier fed by a TMA load, or draining
                       a TMA store group;
  ``wgmma-drain``    — blocked on a WGMMA commit-group drain;
  ``barrier-wait``   — blocked on another warpgroup (producer_acquire with
                       the ring buffer full, or a named barrier) for reasons
                       other than softmax;
  ``softmax-bubble`` — the share of a warpgroup-to-warpgroup wait whose
                       *binding causal chain* ran through a softmax bubble
                       (ping-pong exposure is transitive: the signaler may
                       itself drain WGMMAs queued behind its bubble);
  ``scheduler``      — residual issue delay the DAG does not model (GTO
                       arbitration, issue-width, WGMMA issue-buffer
                       backpressure).

The buckets of one warpgroup sum *exactly* to its idle cycles
(span - lane occupancy) by construction — tested in tests/test_analysis.py.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List

from repro.analysis.dag import DONE, PipelineDAG
from repro.analysis.events import BUBBLE, MMA, TMA
from repro.core import isa
# label parsing lives in obs.labels (single source of truth for the
# cta{i}/{role} convention); role_of is re-exported here for back-compat
from repro.obs.labels import role_of  # noqa: F401

BUCKETS = ("tma-wait", "wgmma-drain", "barrier-wait", "softmax-bubble",
           "scheduler")


# ---------------------------------------------------------------------------
# critical path
# ---------------------------------------------------------------------------

def critical_path(dag: PipelineDAG) -> List[int]:
    """Event ids from a source to the sink along binding predecessors."""
    path = [dag.sink()]
    while True:
        eid = path[-1]
        preds = dag.preds[eid]
        if not preds:
            break
        best, best_rel, best_causal = None, -1, False
        for pe, mode in preds:
            rel = dag.release(pe, mode)
            causal = mode == DONE
            if rel > best_rel or (rel == best_rel and causal and not best_causal):
                best, best_rel, best_causal = pe, rel, causal
        path.append(best)
    path.reverse()
    return path


def path_length(dag: PipelineDAG, path: List[int]) -> int:
    """Arrival time of the sink along the path (== makespan when the walk
    starts from the global sink)."""
    return dag.events[path[-1]].t_done


def path_summary(dag: PipelineDAG, path: List[int]) -> Dict[str, int]:
    """Decompose the path length into time spent per node class.

    Each node contributes (its release to the successor) minus (the previous
    path node's release); contributions telescope to the path length.
    """
    out: Dict[str, int] = defaultdict(int)
    prev_rel = 0
    for i, eid in enumerate(path):
        e = dag.events[eid]
        rel = e.t_done if i + 1 == len(path) else _release_to(dag, eid, path[i + 1])
        contrib = max(0, rel - prev_rel)
        prev_rel = max(prev_rel, rel)
        if e.kind == MMA:
            key = "wgmma"
        elif e.kind == TMA:
            key = "tma"
        elif e.kind == BUBBLE:
            key = "softmax"
        else:
            key = "issue"
        out[key] += contrib
    return dict(out)


def _release_to(dag: PipelineDAG, eid: int, succ: int) -> int:
    for pe, mode in dag.preds[succ]:
        if pe == eid:
            return dag.release(pe, mode)
    return dag.events[eid].t_done


# ---------------------------------------------------------------------------
# stall attribution
# ---------------------------------------------------------------------------



@dataclass
class StallReport:
    per_wg: Dict[str, Dict[str, int]]       # label -> bucket -> cycles
    meta: Dict[str, Dict[str, int]]         # label -> span/busy/idle/instrs
    makespan: int

    def totals(self) -> Dict[str, int]:
        tot: Dict[str, int] = defaultdict(int)
        for b in self.per_wg.values():
            for k, v in b.items():
                tot[k] += v
        return dict(tot)

    def by_role(self) -> Dict[str, Dict[str, int]]:
        """Buckets summed over every warpgroup of each declared role —
        the cross-CTA view keyed by the kernel spec's role names."""
        out: Dict[str, Dict[str, int]] = {}
        for label, buckets in self.per_wg.items():
            acc = out.setdefault(role_of(label), defaultdict(int))
            for k, v in buckets.items():
                acc[k] += v
            acc["idle"] += self.meta[label]["idle"]
            acc["busy"] += self.meta[label]["busy"]
        return {r: dict(b) for r, b in out.items()}


def _chain_bubble_cycles(dag: PipelineDAG, eid: int, lo: int, hi: int) -> int:
    """Bubble cycles of [lo, hi) spent on the *binding-predecessor chain*
    upstream of ``eid``.

    A barrier wait's cause is transitive: the signaling warpgroup may itself
    have been draining WGMMAs that queued behind a softmax bubble two hops
    earlier.  Walking the binding chain (the same argmax-release walk the
    critical path uses) and clipping each chain node's occupancy to the wait
    window measures how much of the wait is ultimately softmax exposure."""
    tot = 0
    cur = eid
    while True:
        preds = dag.preds[cur]
        if not preds:
            break
        best, best_rel = preds[0][0], -1
        for pe, mode in preds:
            rel = dag.release(pe, mode)
            if rel > best_rel:
                best, best_rel = pe, rel
        e = dag.events[best]
        if e.kind == BUBBLE:
            s, t = max(lo, e.t0), min(hi, e.t1)
            if t > s:
                tot += t - s
        cur = best
        if e.t0 <= lo:
            break
    return tot


def _bucket_split(dag: PipelineDAG, eid: int, lo: int, hi: int) -> Dict[str, int]:
    """Split one causal-wait window across buckets (sum == hi - lo)."""
    e = dag.events[eid]
    op = e.op
    wait = hi - lo
    if op == isa.MB_WAIT or op == isa.TMA_WAIT:
        return {"tma-wait": wait}
    if op == isa.WGMMA_WAIT:
        return {"wgmma-drain": wait}
    if op in (isa.ACQUIRE_STAGE, isa.BAR_WAIT):
        # warpgroup-to-warpgroup wait: the share of the window the binding
        # causal chain spent inside softmax bubbles is ping-pong exposure
        bub = min(wait, _chain_bubble_cycles(dag, eid, lo, hi))
        out = {"barrier-wait": wait - bub}
        if bub:
            out["softmax-bubble"] = bub
        return out
    return {"scheduler": wait}


def attribute_stalls(dag: PipelineDAG) -> StallReport:
    per_wg: Dict[str, Dict[str, int]] = {}
    meta: Dict[str, Dict[str, int]] = {}
    for label, eids in dag.threads.items():
        buckets = {b: 0 for b in BUCKETS}
        busy = 0
        for i, eid in enumerate(eids):
            e = dag.events[eid]
            busy += e.t1 - e.t0
            if i == 0:
                continue
            prev_end = dag.events[eids[i - 1]].t1
            gap = e.t0 - prev_end
            if gap <= 0:
                continue
            # the causal wait ends when the latest predecessor releases;
            # anything after that is scheduler delay
            wait = min(gap, max(0, dag.ready[eid] - prev_end))
            sched = gap - wait
            if wait:
                for k, v in _bucket_split(dag, eid, prev_end,
                                          prev_end + wait).items():
                    buckets[k] += v
            buckets["scheduler"] += sched
        first, last = dag.events[eids[0]], dag.events[eids[-1]]
        span = last.t1 - first.t0
        per_wg[label] = buckets
        meta[label] = {"span": span, "busy": busy, "idle": span - busy,
                       "instrs": len(eids)}
    return StallReport(per_wg=per_wg, meta=meta, makespan=dag.makespan)


# ---------------------------------------------------------------------------
# stall timelines (the attribution above, resolved over cycle windows)
# ---------------------------------------------------------------------------

def _spread(acc: Dict[int, float], lo: int, hi: int, cycles: float,
            window: int) -> None:
    """Distribute ``cycles`` uniformly over the windows overlapped by
    ``[lo, hi)`` (float apportionment at the boundary windows)."""
    span = hi - lo
    if span <= 0 or cycles <= 0:
        return
    w = lo - lo % window
    while w < hi:
        seg = min(hi, w + window) - max(lo, w)
        if seg > 0:
            acc[w] = acc.get(w, 0.0) + cycles * seg / span
        w += window


def stall_timeline(dag: PipelineDAG, window: int = 256
                   ) -> Dict[str, Dict[str, Dict[int, float]]]:
    """Per-warpgroup stall buckets resolved over ``window``-cycle windows:
    ``label -> bucket -> {window_start: cycles}``.

    The same walk as :func:`attribute_stalls`, but each bucketed wait
    interval is spread over the windows it overlaps (uniformly within the
    interval; each bucket's windowed values sum to its attribution total).
    This is the PipeEvent-side counter timeline — the engine-sampled
    counters (``obs.counters``) cover bandwidths/occupancy, this covers
    *why lanes idled, when*."""
    out: Dict[str, Dict[str, Dict[int, float]]] = {}
    for label, eids in dag.threads.items():
        acc: Dict[str, Dict[int, float]] = {}
        for i, eid in enumerate(eids):
            if i == 0:
                continue
            e = dag.events[eid]
            prev_end = dag.events[eids[i - 1]].t1
            gap = e.t0 - prev_end
            if gap <= 0:
                continue
            wait = min(gap, max(0, dag.ready[eid] - prev_end))
            sched = gap - wait
            if wait:
                for k, v in _bucket_split(dag, eid, prev_end,
                                          prev_end + wait).items():
                    _spread(acc.setdefault(k, {}), prev_end,
                            prev_end + wait, v, window)
            if sched:
                _spread(acc.setdefault("scheduler", {}), prev_end + wait,
                        e.t0, sched, window)
        out[label] = acc
    return out
