"""Text / JSON rendering for stall attribution and what-if sweeps."""
from __future__ import annotations

from dataclasses import asdict, is_dataclass
from typing import Dict, List, Sequence

from repro.analysis.critical_path import BUCKETS, StallReport


def _fmt_row(cells: Sequence[str], widths: Sequence[int]) -> str:
    return "  ".join(str(c).rjust(w) for c, w in zip(cells, widths))


def render_stall_report(rep: StallReport, top: int = 0) -> str:
    """Per-warpgroup stall table; ``top`` limits to the N widest-idle WGs
    (0 = all). A totals row aggregates every warpgroup."""
    labels = sorted(rep.per_wg,
                    key=lambda l: -rep.meta[l]["idle"])
    if top:
        labels = labels[:top]
    head = ["warpgroup", "span", "busy", "idle", *BUCKETS]
    rows = [head]
    for lbl in labels:
        m, b = rep.meta[lbl], rep.per_wg[lbl]
        rows.append([lbl, m["span"], m["busy"], m["idle"],
                     *[b[k] for k in BUCKETS]])
    tot = rep.totals()
    mt = {k: sum(m[k] for m in rep.meta.values())
          for k in ("span", "busy", "idle")}
    rows.append(["TOTAL", mt["span"], mt["busy"], mt["idle"],
                 *[tot.get(k, 0) for k in BUCKETS]])
    widths = [max(len(str(r[i])) for r in rows) for i in range(len(head))]
    out = [_fmt_row(rows[0], widths),
           _fmt_row(["-" * w for w in widths], widths)]
    out += [_fmt_row(r, widths) for r in rows[1:]]
    out.append(f"(makespan {rep.makespan} cycles; idle buckets sum to idle "
               f"per warpgroup by construction)")
    return "\n".join(out)


def render_whatif_table(rows: List[Dict]) -> str:
    head = ["workload", "machine", "knobs", "base_us", "pred_us", "speedup"]
    table = [head]
    for r in rows:
        table.append([r["workload"], r["machine"], r["knobs_label"],
                      f"{r['base_us']:.1f}", f"{r['pred_us']:.1f}",
                      f"{r['speedup']:.2f}x"])
    widths = [max(len(str(row[i])) for row in table) for i in range(len(head))]
    out = [_fmt_row(table[0], widths),
           _fmt_row(["-" * w for w in widths], widths)]
    out += [_fmt_row(r, widths) for r in table[1:]]
    return "\n".join(out)


def render_critical_path(dag, path: List[int], summary: Dict[str, int],
                         max_nodes: int = 12) -> str:
    """Compressed critical-path listing: class totals + the longest hops."""
    total = max(sum(summary.values()), 1)
    out = ["critical path ({} nodes, {} cycles):".format(
        len(path), dag.events[path[-1]].t_done)]
    for k, v in sorted(summary.items(), key=lambda kv: -kv[1]):
        out.append(f"  {k:10s} {v:>10d} cycles  ({100.0 * v / total:5.1f}%)")
    out.append("  longest hops:")
    hops = sorted(path, key=lambda e: -(dag.events[e].t1 - dag.events[e].t0))
    for eid in hops[:max_nodes]:
        e = dag.events[eid]
        if e.t1 == e.t0:
            continue
        out.append(f"    {e.label:14s} {e.kind:6s} {e.tag or e.op:14s} "
                   f"[{e.t0}, {e.t1})  {e.t1 - e.t0} cycles")
    return "\n".join(out)


def save_json(path: str, obj, *, manifest=True) -> None:
    """Write ``obj`` as JSON.  By default the payload is stamped with an
    ``obs.manifest`` provenance manifest: dict payloads gain a
    ``"manifest"`` key (unless they already carry one), list payloads are
    wrapped as ``{"manifest": ..., "rows": [...]}``.  ``manifest=False``
    writes the object verbatim; ``manifest=<dict>`` stamps a caller-built
    manifest (e.g. a ``SimResult.manifest``) instead of a fresh one."""
    def default(o):
        if is_dataclass(o) and not isinstance(o, type):
            return asdict(o)
        raise TypeError(f"unserializable: {type(o)}")
    if manifest is not False:
        from repro.obs.manifest import build_manifest
        stamp = manifest if isinstance(manifest, dict) else build_manifest()
        if isinstance(obj, dict):
            if "manifest" not in obj:
                obj = {**obj, "manifest": stamp}
        elif isinstance(obj, list):
            obj = {"manifest": stamp, "rows": obj}
    from repro.utils.ioutil import atomic_write_json
    atomic_write_json(path, obj, indent=1, default=default)
