"""Dependency-DAG construction over a PipeEvent trace.

Nodes are the events themselves (event ids are already a topological order:
the tracer creates every event after all of its causal predecessors).  Edges
carry a *release mode*:

  ``end``  — the successor waits for the predecessor's lane occupancy to end
             (program order; issue of an async op);
  ``done`` — the successor waits for the predecessor's effect
             (mbarrier signal, WGMMA group completion, stage release, ...).

Edge kinds reconstructed from event metadata (byteprofile-analysis shape —
build the DAG from the trace, then replay it under perturbed costs):

  * program order within each warpgroup lane;
  * TMA load completion -> the mbarrier wait that needed its signal ordinal;
  * consumer_release -> the producer_acquire blocked on that release ordinal;
  * BAR_ARRIVE -> the BAR_WAIT needing that arrival ordinal;
  * WGMMA execution -> the commit-group drain wait (per-SM tensor-core FIFO
    makes the highest-eid WGMMA with gid <= threshold the binding one);
  * TMA store job -> the store-group drain wait;
  * issue -> async engine op (WGMMA / TMA job);
  * per-SM tensor-core FIFO chain between consecutive WGMMA executions;
  * CTA retirement -> first instructions of the CTA dispatched into the slot.

Every node also gets a ``slack``: measured start minus the latest measured
predecessor release.  Slack is scheduler/arbitration delay the edge set does
not model (GTO issue arbitration, WGMMA issue-buffer backpressure); replay
keeps it as a fixed per-node cost, which is what makes a x1.0 replay
reproduce the simulated schedule exactly.
"""
from __future__ import annotations

import bisect
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import events as ev_mod
from repro.analysis.events import BUBBLE, ISSUE, MMA, TMA, PipeEvent
from repro.core import isa

# release modes
END, DONE = "end", "done"


@dataclass
class PipelineDAG:
    events: List[PipeEvent]
    preds: List[List[Tuple[int, str]]]          # eid -> [(pred_eid, mode)]
    ready: List[int]                            # measured max pred release
    slack: List[int]                            # t0 - ready (>= 0)
    threads: "Dict[str, List[int]]"             # label -> lane eids in order
    makespan: int
    negative_slack: int                         # diagnostic: clamped edges

    def release(self, eid: int, mode: str) -> int:
        e = self.events[eid]
        return e.t1 if mode == END else e.t_done

    def sink(self) -> int:
        return max(range(len(self.events)),
                   key=lambda i: (self.events[i].t_done, i))


def _prefix_max_by_gid(entries: List[Tuple[int, int]]):
    """[(gid, eid)] -> (sorted gids, prefix-max eids) for <=-threshold query."""
    entries = sorted(entries)
    gids = [g for g, _ in entries]
    pmax: List[int] = []
    cur = -1
    for _, e in entries:
        cur = max(cur, e)
        pmax.append(cur)
    return gids, pmax


def build(events: Sequence[PipeEvent],
          dispatch_parent: Optional[Dict[int, int]] = None) -> PipelineDAG:
    """Construct the dependency DAG for one recorded engine run."""
    dispatch_parent = dispatch_parent or {}
    n = len(events)
    preds: List[List[Tuple[int, str]]] = [[] for _ in range(n)]
    threads: Dict[str, List[int]] = defaultdict(list)

    # --- index signal producers -----------------------------------------
    load_sig: Dict[Tuple[int, int, int], int] = {}     # (cta,sid,ord)->eid
    release_sig: Dict[Tuple[int, int, int], int] = {}
    arrive_sig: Dict[Tuple[int, int, int], int] = {}
    mma_by_thread: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    store_by_thread: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    cta_events: Dict[int, List[int]] = defaultdict(list)
    for e in events:
        cta_events[e.cta].append(e.eid)
        if e.kind in (ISSUE, BUBBLE):
            threads[e.label].append(e.eid)
            if e.op == isa.RELEASE_STAGE:
                release_sig[(e.cta, e.sid, e.dep_n)] = e.eid
            elif e.op == isa.BAR_ARRIVE:
                arrive_sig[(e.cta, e.bid, e.dep_n)] = e.eid
        elif e.kind == TMA and e.op == ev_mod.TMA_LOAD_JOB:
            load_sig[(e.cta, e.sid, e.dep_n)] = e.eid
        elif e.kind == TMA:
            store_by_thread[e.label].append((e.gid, e.eid))
        elif e.kind == MMA:
            mma_by_thread[e.label].append((e.gid, e.eid))

    mma_idx = {lbl: _prefix_max_by_gid(v) for lbl, v in mma_by_thread.items()}

    # --- terminal events per CTA (for dispatch edges) --------------------
    def terminals(cta: int) -> List[int]:
        last: Dict[Tuple[str, str], int] = {}
        for eid in cta_events[cta]:
            e = events[eid]
            last[(e.label, e.kind if e.kind in (MMA, TMA) else "lane")] = eid
        return sorted(last.values())

    # --- edges ------------------------------------------------------------
    last_lane: Dict[str, int] = {}
    last_mma_on_sm: Dict[int, int] = {}
    for e in events:
        p = preds[e.eid]
        if e.kind in (ISSUE, BUBBLE):
            prev = last_lane.get(e.label)
            if prev is not None:
                p.append((prev, END))                      # program order
            elif e.cta in dispatch_parent:
                for t in terminals(dispatch_parent[e.cta]):
                    p.append((t, DONE))                    # slot hand-off
            last_lane[e.label] = e.eid
            op = e.op
            if op == isa.MB_WAIT:
                src = load_sig.get((e.cta, e.sid, e.dep_n))
                if src is not None:
                    p.append((src, DONE))
            elif op == isa.ACQUIRE_STAGE and e.dep_n > 0:
                src = release_sig.get((e.cta, e.sid, e.dep_n))
                if src is not None:
                    p.append((src, DONE))
            elif op == isa.BAR_WAIT:
                src = arrive_sig.get((e.cta, e.bid, e.dep_n))
                if src is not None:
                    p.append((src, DONE))
            elif op == isa.WGMMA_WAIT:
                idx = mma_idx.get(e.label)
                if idx:
                    gids, pmax = idx
                    i = bisect.bisect_right(gids, e.dep_n) - 1
                    if i >= 0 and pmax[i] < e.eid:
                        p.append((pmax[i], DONE))
            elif op == isa.TMA_WAIT:
                for gid, seid in store_by_thread.get(e.label, ()):
                    if gid <= e.dep_n and seid < e.eid:
                        p.append((seid, DONE))
        else:                                              # engine events
            if e.src >= 0:
                p.append((e.src, END))
            if e.kind == MMA:
                prev = last_mma_on_sm.get(e.sm)
                if prev is not None:
                    p.append((prev, DONE))                 # TC FIFO chain
                last_mma_on_sm[e.sm] = e.eid

    # --- slack -----------------------------------------------------------
    ready = [0] * n
    slack = [0] * n
    negative = 0
    for e in events:
        r = 0
        for pe, mode in preds[e.eid]:
            v = events[pe].t1 if mode == END else events[pe].t_done
            if v > r:
                r = v
        ready[e.eid] = r
        s = e.t0 - r
        if s < 0:
            negative += 1
            s = 0
        slack[e.eid] = s

    makespan = max((e.t_done for e in events), default=0)
    return PipelineDAG(events=list(events), preds=preds, ready=ready,
                       slack=slack, threads=dict(threads), makespan=makespan,
                       negative_slack=negative)


def from_engine(eng) -> PipelineDAG:
    """Build the DAG from an Engine run with an attached tracer."""
    if eng.tracer is None:
        raise ValueError("engine was run without an EventTracer "
                         "(pass record_gantt=True or tracer=EventTracer())")
    return build(eng.tracer.events, eng.tracer.dispatch_parent)
