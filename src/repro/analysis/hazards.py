"""Runtime hazard sanitizer + deadlock explainer (the dynamic half of the
kprog verifier, ``repro.core.kprog.verify``).

Two duck-typed services over live engine state, both bit-neutral in the
PR-7 counter-sink sense — they only *read* simulated state (plus their own
private bookkeeping), never mutate it, so attaching them cannot change a
single simulated cycle:

  * :class:`HazardSanitizer` — ``Engine(sanitize=True)``.  A TSan-style
    per-event cross-check of the ring protocol invariants the static
    verifier proves over the lowered streams: every TMA refill of a ring
    stage is covered by a fresh ACQUIRE (unguarded-load / write-after-read
    race), every RELEASE closes a reader window that an MB_WAIT opened
    (release-without-wait), and windows still open at CTA retirement are
    leaked stages (wait-release-mismatch).  Cost is one ``is not None``
    test per issued instruction when disabled and a couple of dict
    operations on sync opcodes when enabled.
  * :func:`explain_deadlock` — called by the engine the moment a run loop
    concludes nothing can ever progress again.  Snapshots every blocked
    thread (opcode, sid/bid, need vs. have counts), reconstructs the
    inter-warpgroup wait-for graph from the threads' remaining streams,
    and extracts a minimal witness cycle — the dynamic analogue of the
    static verifier's deadlock finding, surfaced through
    ``SimResult.deadlock_info`` and the obs report instead of a bare
    ``deadlocked=True``.

Neither imports the engine (duck-typing keeps ``core`` -> ``analysis``
one-directional at module-import time).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core import isa

# codes mirror the static verifier's catalogue (docs/verification.md)
UNGUARDED_LOAD = "unguarded-load"
RELEASE_WITHOUT_WAIT = "release-without-wait"
WAIT_RELEASE_MISMATCH = "wait-release-mismatch"
RACE_WAR = "race-war"


@dataclass(frozen=True)
class HazardIssue:
    """One dynamic invariant violation, anchored to a simulated cycle."""
    cycle: int
    code: str
    cta: str           # CTA trace name
    wg: str            # thread label
    pc: int
    op: str
    detail: str

    def render(self) -> str:
        return (f"[cycle {self.cycle}] {self.code}: {self.cta}/{self.wg}"
                f"@{self.pc} {self.op} — {self.detail}")


class HazardSanitizer:
    """Per-event ring-protocol cross-check (``Engine(sanitize=True)``).

    State is keyed by CTA launch index and dropped at retirement, so
    memory stays bounded by residency, not launch size.  Issues are capped
    at ``max_issues`` (the total count keeps incrementing past the cap).
    """

    def __init__(self, max_issues: int = 256):
        self.issues: List[HazardIssue] = []
        self.n_issues = 0
        self.max_issues = max_issues
        # cta idx -> {sid: ring name}; None for CTAs without ring metadata
        self._rings: Dict[int, Optional[Dict[int, str]]] = {}
        self._armed: Dict[Tuple[int, int], int] = {}     # (cta, sid) -> pc
        # (cta, sid) -> {wg_id: open reader windows}
        self._windows: Dict[Tuple[int, int], Dict[int, int]] = {}

    # ------------------------------------------------------------------
    def _issue(self, cycle: int, th, pc: int, op: str, code: str,
               detail: str) -> None:
        self.n_issues += 1
        if len(self.issues) < self.max_issues:
            self.issues.append(HazardIssue(
                cycle, code, th.cta.trace.name, th.label, pc, op, detail))

    def _ring_map(self, cta) -> Optional[Dict[int, str]]:
        m = self._rings.get(cta.idx, -1)
        if m != -1:
            return m
        rings = getattr(cta.trace, "rings", None)
        m = None
        if rings:
            m = {}
            for name, sids in rings.items():
                for s in sids:
                    m[s] = name
        self._rings[cta.idx] = m
        return m

    # ------------------------------------------------------------------
    def on_execute(self, cycle: int, th, ins) -> None:
        """Hook at instruction issue (top of ``SM._execute``)."""
        rm = self._ring_map(th.cta)
        if rm is None or ins.sid not in rm:
            return
        key = (th.cta.idx, ins.sid)
        op = ins.op
        if op == isa.ACQUIRE_STAGE:
            if key in self._armed:
                self._issue(cycle, th, th.pc, op, WAIT_RELEASE_MISMATCH,
                            f"re-acquires sid {ins.sid} (ring "
                            f"{rm[ins.sid]!r}) while the acquire armed at "
                            f"pc {self._armed[key]} was never consumed by "
                            f"a load")
            self._armed[key] = th.pc
        elif op == isa.TMA_TENSOR:
            armed = self._armed.pop(key, None)
            readers = self._windows.get(key)
            if armed is None:
                code = RACE_WAR if readers else UNGUARDED_LOAD
                who = (f"; readers still in the stage: "
                       f"{sorted(readers)}" if readers else "")
                self._issue(cycle, th, th.pc, op, code,
                            f"refills sid {ins.sid} (ring {rm[ins.sid]!r}) "
                            f"without a covering ACQUIRE_STAGE{who}")
        elif op == isa.MB_WAIT:
            w = self._windows.setdefault(key, {})
            w[th.wg_id] = w.get(th.wg_id, 0) + 1
        elif op == isa.RELEASE_STAGE:
            w = self._windows.get(key)
            if not w or not w.get(th.wg_id):
                self._issue(cycle, th, th.pc, op, RELEASE_WITHOUT_WAIT,
                            f"releases sid {ins.sid} (ring {rm[ins.sid]!r}) "
                            f"without an open reader window (no prior "
                            f"MB_WAIT by this warpgroup)")
            else:
                w[th.wg_id] -= 1
                if not w[th.wg_id]:
                    del w[th.wg_id]

    def on_cta_retired(self, cycle: int, cta) -> None:
        """Windows still open at retirement are leaked ring stages."""
        rm = self._rings.pop(cta.idx, None)
        for key in [k for k in self._windows if k[0] == cta.idx]:
            w = self._windows.pop(key)
            leaked = {wg: n for wg, n in w.items() if n}
            if leaked and rm:
                th = cta.threads[min(leaked)]
                self._issue(cycle, th, -1, "", WAIT_RELEASE_MISMATCH,
                            f"CTA retired with {sum(leaked.values())} "
                            f"reader window(s) still open on sid {key[1]} "
                            f"(ring {rm.get(key[1])!r}): tiles were waited "
                            f"on but never released")
        for key in [k for k in self._armed if k[0] == cta.idx]:
            del self._armed[key]

    def render(self) -> str:
        head = f"sanitizer: {self.n_issues} issue(s)"
        if self.n_issues > len(self.issues):
            head += f" (showing first {len(self.issues)})"
        return "\n".join([head] + [i.render() for i in self.issues])


# ---------------------------------------------------------------------------
# deadlock explanation
# ---------------------------------------------------------------------------

def _need_have(th, ins) -> Tuple[str, int, int]:
    """(operand description, needed count, current count) for a blocking
    instruction, mirroring ``SM._cond_met``."""
    cta = th.cta
    op = ins.op
    if op == isa.MB_WAIT:
        return (f"sid {ins.sid}", th.mb_expected.get(ins.sid, 0) + 1,
                cta.mbarrier.get(ins.sid, 0))
    if op == isa.ACQUIRE_STAGE:
        use = th.acq_count.get(ins.sid, 0)
        return (f"sid {ins.sid}", use * cta.n_consumers,
                cta.stage_releases.get(ins.sid, 0))
    if op == isa.BAR_WAIT:
        return (f"bid {ins.bid}", ins.n, cta.bar_arrivals.get(ins.bid, 0))
    if op == isa.WGMMA_WAIT:
        return (f"gid {ins.gid} (<= {ins.n} outstanding)", ins.n,
                sum(1 for g in th.wgmma_out if g <= ins.gid))
    if op == isa.TMA_WAIT:
        return (f"gid {ins.gid} (<= {ins.n} outstanding)", ins.n,
                sum(1 for g in th.tma_out if g <= ins.gid))
    return ("", 0, 0)


def _providers(th, ins) -> List[str]:
    """Labels of same-CTA threads whose remaining stream contains an op
    that would advance ``th``'s blocked condition."""
    op = ins.op
    if op == isa.MB_WAIT:
        want, attr, val = isa.TMA_TENSOR, "sid", ins.sid
    elif op == isa.ACQUIRE_STAGE:
        want, attr, val = isa.RELEASE_STAGE, "sid", ins.sid
    elif op == isa.BAR_WAIT:
        want, attr, val = isa.BAR_ARRIVE, "bid", ins.bid
    else:
        return []
    out = []
    for other in th.cta.threads:
        start = other.pc + (1 if other is th else 0)
        if any(i.op == want and getattr(i, attr) == val
               for i in other.trace[start:]):
            out.append(other.label)
    return out


def _shortest_cycle_labels(
        edges: Dict[str, List[str]]) -> Optional[List[str]]:
    best: Optional[List[str]] = None
    for start in sorted(edges):
        prev: Dict[str, Optional[str]] = {start: None}
        q = deque([start])
        found: Optional[List[str]] = None
        while q and found is None:
            u = q.popleft()
            for v in edges.get(u, ()):
                if v == start:
                    path, node = [], u
                    while node is not None:
                        path.append(node)
                        node = prev[node]
                    found = list(reversed(path))
                    break
                if v not in prev:
                    prev[v] = u
                    q.append(v)
        if found is not None and (best is None or len(found) < len(best)):
            best = found
    return best


def explain_deadlock(engine) -> Dict[str, Any]:
    """Snapshot why a run loop concluded no progress is possible.

    Returns a JSON-serializable dict: ``cycle``, ``n_blocked``, per-thread
    ``blocked`` entries (label, CTA, sm, pc, opcode, operand, need/have,
    ``waits_on`` provider labels) and the minimal wait-for ``cycle_witness``
    (list of labels) when a circular wait exists among resident threads.
    Read-only over engine state — safe to call from the deadlocked loops.
    """
    blocked: List[Dict[str, Any]] = []
    edges: Dict[str, List[str]] = {}
    for sm in engine.sms:
        for th in sm.threads():
            if th.done():
                continue
            ins = th.trace[th.pc]
            operand, need, have = _need_have(th, ins)
            providers = _providers(th, ins)
            blocked.append({
                "label": th.label,
                "cta": th.cta.trace.name,
                "sm": sm.sm_id,
                "pc": th.pc,
                "op": ins.op,
                "operand": operand,
                "need": need,
                "have": have,
                "waits_on": providers,
            })
            if providers:
                edges[th.label] = providers
    witness = _shortest_cycle_labels(edges) if edges else None
    return {
        "cycle": engine.cycle,
        "n_blocked": len(blocked),
        "launched": engine.launched,
        "retired": engine.retired,
        "blocked": blocked,
        "cycle_witness": witness,
    }


def render_deadlock(info: Dict[str, Any], limit: int = 8) -> List[str]:
    """Human-readable lines for a deadlock-info dict (obs report)."""
    lines = [f"  deadlock at cycle {info['cycle']}: {info['n_blocked']} "
             f"thread(s) blocked, {info['retired']}/{info['launched']} "
             f"CTAs retired"]
    if info.get("cycle_witness"):
        lines.append("    circular wait: "
                     + " -> ".join(info["cycle_witness"]
                                   + info["cycle_witness"][:1]))
    for b in info["blocked"][:limit]:
        lines.append(f"    {b['label']}@{b['pc']} {b['op']} {b['operand']}"
                     f" (need {b['need']}, have {b['have']})"
                     + (f" <- {', '.join(b['waits_on'])}"
                        if b["waits_on"] else " <- nothing pending"))
    if len(info["blocked"]) > limit:
        lines.append(f"    ... and {len(info['blocked']) - limit} more")
    return lines
