"""Optimizer substrate: AdamW + global-norm clipping + LR schedules
(cosine and MiniCPM's WSD), pure-pytree implementation.

Optimizer state shards exactly like the parameters (FSDP): m/v inherit the
param PartitionSpecs, so 100B+ models fit (DESIGN.md §7).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | wsd | const
    wsd_stable_frac: float = 0.8      # WSD: fraction of steps at peak LR
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def schedule_lr(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return cfg.lr * warm * cos
    if cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM): flat at peak, then 1-sqrt decay
        stable_end = cfg.warmup_steps + cfg.wsd_stable_frac * (
            cfg.total_steps - cfg.warmup_steps)
        t = jnp.clip((step - stable_end) / jnp.maximum(
            cfg.total_steps - stable_end, 1), 0.0, 1.0)
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1.0 - jnp.sqrt(t))
        return cfg.lr * warm * jnp.where(step < stable_end, 1.0, decay)
    raise ValueError(cfg.schedule)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms/bias/1-d params."""
    last = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return last not in ("b", "scale", "bias")


def apply_updates(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path) and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_m, new_v, step), {
        "grad_norm": gnorm, "lr": lr}
