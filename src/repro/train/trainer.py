"""Train step factory: microbatched gradient accumulation, remat, MoE aux
loss, gradient compression hook, and sharding-aware jit wiring.

``make_train_step(cfg, run)`` returns a function
    train_step(state, batch) -> (state, metrics)
suitable for ``jax.jit(..., in_shardings=..., donate_argnums=0)``. The
gradient-accumulation scan defers the cross-replica gradient reduction to
the single optimizer application (one psum per step instead of one per
microbatch — the standard comm/compute overlap trick).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.train import optimizer as opt
from repro.train.loss import chunked_cross_entropy


@dataclass(frozen=True)
class RunConfig:
    microbatches: int = 1
    remat: str = "full"
    moe_impl: str = "einsum"
    moe_aux_weight: float = 0.01
    loss_chunk: int = 2048
    grad_dtype: str = "float32"        # gradient accumulator dtype
    grad_compress: str = "none"        # none | int8 (error-feedback)
    cast_params: str = "none"          # none | bfloat16: fwd/bwd compute
                                       # params (fp32 masters kept in state;
                                       # FSDP all-gathers move bf16 — §Perf)
    attn_chunk: int = 512              # flash_ref KV chunk (§Perf knob)
    attn_pv_bf16: bool = False         # FA3-style P-tile cast (§Perf knob)
    opt: opt.OptConfig = opt.OptConfig()


class TrainState(NamedTuple):
    params: dict
    opt_state: opt.OptState
    ef_error: Optional[dict]           # int8 compression error feedback


def init_state(cfg, run: RunConfig, key):
    params = api.init(cfg, key)
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if run.grad_compress == "int8" else None)
    return TrainState(params=params, opt_state=opt.init(params), ef_error=ef)


def _quantize_int8(g, err):
    """Error-feedback int8 compression: models a compressed gradient
    all-reduce (the quantize->sum->dequantize pipeline); the quantization
    residual is fed back into the next step."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq


def make_loss_fn(cfg, run: RunConfig):
    from functools import partial as _partial
    from repro.models.attention import flash_ref

    attn_fn = (None if run.attn_chunk == 512 and not run.attn_pv_bf16
               else _partial(flash_ref, chunk=run.attn_chunk,
                             pv_bf16=run.attn_pv_bf16))

    def loss_fn(params, mb):
        if run.cast_params != "none":
            cdt = jnp.dtype(run.cast_params)
            params = jax.tree.map(
                lambda p: p.astype(cdt)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        hidden, aux = api.forward_hidden(
            cfg, params, mb, remat=run.remat, moe_impl=run.moe_impl,
            attn_fn=attn_fn)
        s_tok = mb["labels"].shape[1]
        loss, w = chunked_cross_entropy(
            hidden[:, -s_tok:], api.unembed_table(cfg, params), mb["labels"],
            chunk=run.loss_chunk)
        total = loss + run.moe_aux_weight * jnp.asarray(aux, jnp.float32)
        return total, {"loss": loss, "aux": jnp.asarray(aux, jnp.float32)}
    return loss_fn


def make_train_step(cfg, run: RunConfig, grad_specs=None):
    """grad_specs: optional PartitionSpec pytree matching the params.
    Constraining the gradient accumulator to the parameter sharding turns
    the per-microbatch gradient reduction into a reduce-scatter onto the
    FSDP shards instead of a full all-reduce of replicated gradients
    (§Perf: 2x wire bytes + no replicated accumulator in HBM)."""
    loss_fn = make_loss_fn(cfg, run)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def train_step(state: TrainState, batch):
        params = state.params
        n_mb = run.microbatches

        if n_mb == 1:
            (_, metrics), grads = grad_fn(params, batch)
            grads = _constrain(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((n_mb, b // n_mb) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            gdt = jnp.dtype(run.grad_dtype)

            def body(acc, mb):
                (_, m), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(gdt), acc, g)
                return _constrain(acc), m
            zero = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params))
            grads, ms = jax.lax.scan(body, zero, mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = jax.tree.map(lambda m: m.mean(), ms)

        ef = state.ef_error
        if run.grad_compress == "int8":
            pairs = jax.tree.map(_quantize_int8, grads, ef)
            grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            ef = jax.tree.map(lambda t: t[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))

        new_params, new_opt, om = opt.apply_updates(
            run.opt, params, grads, state.opt_state)
        metrics = dict(metrics, **om)
        return TrainState(new_params, new_opt, ef), metrics

    return train_step
