"""Loss: chunked-vocab cross entropy.

The (T, V) logits matrix is never materialized for the whole batch — the
final projection + log-sum-exp run per token chunk under a lax.scan whose
body is rematerialized, bounding peak memory at (chunk, V) while keeping the
matmul MXU-shaped. This matters for 100k+ vocabularies (qwen, command-r).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_cross_entropy(hidden, w_unembed, labels, *, chunk: int = 2048,
                          ignore_index: int = -1):
    """hidden: (B,S,d); w_unembed: (d,V); labels: (B,S) int32.

    Returns (mean_nll over non-ignored, total_weight).
    """
    B, S, d = hidden.shape
    T = B * S
    h = hidden.reshape(T, d)
    y = labels.reshape(T)
    chunk = min(chunk, T)
    n = -(-T // chunk)
    pad = n * chunk - T
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        y = jnp.pad(y, ((0, pad),), constant_values=ignore_index)
    hc = h.reshape(n, chunk, d)
    yc = y.reshape(n, chunk)

    def body(acc, inp):
        hx, yx = inp
        logits = (hx.astype(jnp.bfloat16) @ w_unembed.astype(jnp.bfloat16)
                  ).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yx, 0)[:, None], axis=-1)[:, 0]
        valid = (yx != jnp.asarray(ignore_index)).astype(jnp.float32)
        nll = (lse - gold) * valid
        loss_sum, w_sum = acc
        return (loss_sum + jnp.sum(nll), w_sum + jnp.sum(valid)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (loss_sum, w_sum), _ = jax.lax.scan(body, (0.0, 0.0), (hc, yc))
    return loss_sum / jnp.maximum(w_sum, 1.0), w_sum
