"""Flash-decode Pallas TPU kernel: one new token attending to a KV cache.

Each grid step processes one (batch, kv-head) pair and one KV-cache tile;
all G query heads of the KV head ride along in the sublane dimension (GQA
reuse — one K/V fetch serves G heads, the reuse the paper's Eq. 2 counts).
Emits per-shard (o, m, l) partials when ``return_partials`` so sequence-
sharded caches (SP, long_500k) can be merged with the distributed
log-sum-exp combine in models/attention.py::merge_partial_attn.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out, l_out,
                   acc_ref, m_ref, l_ref, *, scale, block_k):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols >= len_ref[0], NEG_INF, s)        # (G, bk)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)   # UNNORMALIZED acc
        m_out[0, 0] = m_ref[...]
        l_out[0, 0] = l_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_k", "return_partials", "interpret"))
def flash_decode(q, k_cache, v_cache, cache_len, *,
                 block_k: int = DEFAULT_BLOCK_K,
                 return_partials: bool = False, interpret: bool = False):
    """q: (B, H, D); caches: (B, Hkv, S, D); cache_len: scalar int32.

    Returns (B, H, D), or ((B,H,D) unnormalized fp32 acc, m (B,H), l (B,H))
    when return_partials (for cross-shard merge).
    """
    B, H, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    bk = min(block_k, S)
    Sp = -(-S // bk) * bk
    if Sp != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    qg = q.reshape(B, Hkv, G, D)
    clen = jnp.minimum(jnp.asarray(cache_len, jnp.int32), S).reshape(1)

    grid = (B, Hkv, Sp // bk)
    acc, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(clen, qg, k_cache, v_cache)

    if return_partials:
        return (acc.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, H, D).astype(q.dtype)
