"""FlashAttention forward Pallas TPU kernel (the paper's subject workload).

TPU-native adaptation of the FA3 pipeline (DESIGN.md §3): the producer/
consumer WarpGroup split becomes the Mosaic grid pipeline — the async DMA
engine double-buffers the next (K, V) tile into VMEM (the TMA analogue)
while the MXU consumes the current one; softmax (VPU) overlaps the MXU the
way FA3's ping-pong consumers overlap WGMMA.

Tiling: grid (B, H, L/block_q, S/block_k), S innermost ("arbitrary" —
carries the online-softmax state in VMEM scratch across j). Block sizes come
from core/tpu/autotune.py (SimFA-TPU picks them by modeling the pipeline,
mirroring how FA3 picks T_M/T_N by profiling).

GQA: KV index maps h -> h // G so all G query heads of a KV head reuse the
same K/V tiles (the L2-reuse structure the paper's Eq. 2 counts).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, causal: bool, block_q: int, block_k: int,
                      seq_k: int):
    i = pl.program_id(2)          # q block index
    j = pl.program_id(3)          # kv block index
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * block_q
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols >= seq_k
        if causal:
            mask |= cols > rows
        s = jnp.where(mask, NEG_INF, s)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # causal block skip: tiles strictly above the triangle are no-ops
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "debug"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False, debug: bool = False):
    """q: (B, H, L, D); k/v: (B, Hkv, S, D) -> (B, H, L, D)."""
    B, H, L, D = q.shape
    _, Hkv, S, _ = k.shape
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, L)
    bk = min(block_k, S)
    # pad sequence dims to block multiples (masked out in-kernel)
    Lp, Sp = -(-L // bq) * bq, -(-S // bk) * bk
    if Lp != L:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Lp - L), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    grid = (B, H, Lp // bq, Sp // bk)
    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal,
        block_q=bq, block_k=bk, seq_k=S)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
        debug=debug,
    )(q, k, v)
    return out[:, :, :L]
