"""Pure-jnp oracles for the Pallas kernels (sweep-tested in tests/)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,H,L,D); k/v: (B,Hkv,S,D). Materializing softmax reference."""
    B, H, L, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, L, D).astype(jnp.float32)
    s = jnp.einsum("bhgld,bhsd->bhgls", qg, k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(D)
    if causal:
        mask = jnp.arange(S)[None, :] > jnp.arange(L)[:, None]
        s = jnp.where(mask[None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgls,bhsd->bhgld", p, v.astype(jnp.float32))
    return o.reshape(B, H, L, D).astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, cache_len):
    """q: (B,H,D); caches: (B,Hkv,S,D)."""
    B, H, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache.astype(jnp.float32))
    s *= 1.0 / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
