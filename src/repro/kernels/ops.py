"""jit'd public wrappers around the Pallas kernels with CPU dispatch.

On the TPU target the Pallas kernels run natively; on the CPU host (this
container, and the multi-pod dry-run) `mode` selects:
  - "interpret": execute the kernel body in the Pallas interpreter
    (correctness tests),
  - "reference": the pure-XLA online-softmax path with identical math
    (dry-run lowering; Pallas TPU kernels don't lower for the CPU backend).
Block sizes default to the SimFA-TPU autotuner's choice.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.models import attention as _attn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mha_forward(q, k, v, *, causal: bool = True, block_q: int = 128,
                block_k: int = 128, mode: Optional[str] = None):
    """Layout: q (B, L, H, D); k/v (B, S, Hkv, D) — model-side layout."""
    if mode is None:
        mode = "pallas" if _on_tpu() else "reference"
    if mode == "reference":
        return _attn.flash_ref(q, k, v, causal=causal, chunk=block_k)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=(mode == "interpret"))
    return o.transpose(0, 2, 1, 3)


def decode_forward(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                   mode: Optional[str] = None, return_partials: bool = False):
    """Layout: q (B, 1, H, D); caches (B, S, Hkv, D) — model-side layout."""
    if mode is None:
        mode = "pallas" if _on_tpu() else "reference"
    B, L, H, D = q.shape
    if mode == "reference":
        if return_partials:
            valid = jnp.arange(k_cache.shape[1])[None, :] < jnp.reshape(cache_len, (-1, 1))
            o, m, l = _attn.decode_attend_partial(q, k_cache, v_cache, valid)
            return o[:, 0].reshape(B, H, D), m[:, 0].reshape(B, H), l[:, 0].reshape(B, H)
        return _attn.decode_attend(q, k_cache, v_cache, cache_len)
    qt = q.reshape(B, H, D)
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    out = _fd.flash_decode(qt, kt, vt, cache_len, block_k=block_k,
                           return_partials=return_partials,
                           interpret=(mode == "interpret"))
    if return_partials:
        return out
    return out.reshape(B, 1, H, D)
