"""Checkpoint manager: async save, atomic publish, retention, resharding
restore — the fault-tolerance substrate (DESIGN.md §7).

Layout per step:
    <dir>/step_<N>.tmp/       (written)
    <dir>/step_<N>/           (atomic rename on completion)
        manifest.json         (paths, shapes, dtypes, step, mesh fingerprint)
        arr_<i>.npy           (one file per leaf, host-gathered)

Restore: arrays are loaded host-side and ``jax.device_put`` with the
*target* sharding — a checkpoint written on one mesh restores onto any
other (elastic scale-up/down), which is what makes preemption recovery and
re-sharded restarts work.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: Optional[bool] = None):
        """Snapshot to host memory synchronously, write to disk (async by
        default so training continues during I/O)."""
        self.wait()                              # one outstanding save max
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]   # device->host now
        if blocking is None:
            blocking = not self.async_save
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host), daemon=True)
            self._thread.start()

    def _write_guarded(self, step, host):
        try:
            self._write(step, host)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for i, a in enumerate(host):
            np.save(tmp / f"arr_{i}.npy", a)
            manifest["leaves"].append(
                {"file": f"arr_{i}.npy", "shape": list(a.shape),
                 "dtype": str(a.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                         # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {e}") from e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue            # incomplete save: never published
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None):
        """Load into the structure (and shardings) of ``target``.

        ``target`` may be a pytree of arrays or ShapeDtypeStructs; shapes
        and dtypes are validated against the manifest. With ``shardings``
        the leaves are device_put with the new layout (elastic restore)."""
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        t_leaves, treedef = _flatten(target)
        if len(manifest["leaves"]) != len(t_leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, target "
                f"has {len(t_leaves)} — incompatible structure")
        s_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(t_leaves))
        out = []
        for meta, t, s in zip(manifest["leaves"], t_leaves, s_leaves):
            a = np.load(d / meta["file"])
            if tuple(a.shape) != tuple(t.shape):
                raise ValueError(f"shape mismatch {a.shape} vs {t.shape}")
            a = a.astype(t.dtype)
            out.append(jax.device_put(a, s) if s is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, target, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings=shardings)
