"""Perfetto / Chrome ``trace_event`` export of the pipeline trace.

Lowers a recorded :class:`~repro.analysis.events.EventTracer` trace plus
optional :class:`~repro.obs.counters.CounterSink` timelines to the Chrome
``trace_event`` JSON format (the "JSON object format": ``{"traceEvents":
[...]}``), loadable in ui.perfetto.dev or ``chrome://tracing`` — replacing
squinting at ``gantt.render_text`` with a real zoomable timeline.

Mapping (1 trace microsecond == 1 simulated cycle; real time at
``freq_ghz`` is noted in ``otherData``):

  * one thread per warpgroup label ``cta{i}/{role}`` (named via ``M``
    metadata events, sorted by CTA launch index);
  * softmax bubbles -> complete ``X`` slices on the warpgroup thread;
  * instruction issues -> zero-duration ``X`` slices (visible when zoomed;
    waits/acquires carry their ordinal operands in ``args``);
  * TMA jobs and WGMMA executions -> ``b``/``e`` async slices (they overlap
    the issuing lane and each other), categorized ``tma`` / ``wgmma``;
  * issue -> engine-op causality (``PipeEvent.src``) -> ``s``/``f`` flow
    arrows, so clicking a WGMMA shows which instruction launched it;
  * counter timelines -> ``C`` counter tracks (DRAM GB/s, L2 hit %, TC
    busy %, TMA in-flight lines, resident CTAs, per-(cta, ring) occupancy,
    per-role stall-bucket cycles).

Schema guarantees (enforced by ``tests/test_obs.py``): the export is valid
JSON, ``ts`` is monotonically non-decreasing per ``tid``, and every flow
arrow's start (``s``) and finish (``f``) endpoints both exist.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.labels import cta_of

PID = 0


def _percent(x: float) -> float:
    return round(100.0 * x, 2)


def build_trace(trace=None, counters=None, manifest: Optional[dict] = None,
                *, name: str = "sim-fa", ring_track_limit: int = 8,
                include_stalls: bool = True,
                stall_window: int = 256) -> Dict[str, Any]:
    """Build the ``trace_event`` JSON object (dict) from a PipeEvent trace
    and/or counter sink.  ``ring_track_limit`` caps how many CTAs get
    per-ring occupancy counter tracks (a full launch has hundreds of CTAs;
    unlimited with ``ring_track_limit=None``)."""
    events: List[Dict[str, Any]] = []
    meta: List[Dict[str, Any]] = []
    meta.append({"ph": "M", "pid": PID, "name": "process_name",
                 "args": {"name": name}})

    tids: Dict[str, int] = {}

    def tid_for(label: str) -> int:
        t = tids.get(label)
        if t is None:
            t = tids[label] = len(tids) + 1
        return t

    if trace is not None:
        _emit_pipe_events(trace, events, tid_for)
    if counters is not None:
        _emit_counter_tracks(counters, events, ring_track_limit)
    if include_stalls and trace is not None and trace.events:
        _emit_stall_tracks(trace, events, stall_window)

    for label, t in tids.items():
        c = cta_of(label)
        meta.append({"ph": "M", "pid": PID, "tid": t, "name": "thread_name",
                     "args": {"name": label}})
        meta.append({"ph": "M", "pid": PID, "tid": t,
                     "name": "thread_sort_index",
                     "args": {"sort_index": c if c is not None else t}})

    events.sort(key=lambda e: (e["ts"], e.get("tid", 0), e["ph"] != "e"))
    other: Dict[str, Any] = {"time_unit": "1 us == 1 simulated cycle"}
    if manifest:
        other["manifest"] = manifest
    return {"traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": other}


def export_trace(path: str, trace=None, counters=None,
                 manifest: Optional[dict] = None, **kw) -> Dict[str, Any]:
    """Build and write the trace JSON to ``path``; returns the dict."""
    obj = build_trace(trace, counters, manifest, **kw)
    from repro.utils.ioutil import atomic_write_json
    atomic_write_json(path, obj, indent=None, separators=(",", ":"))
    return obj


# ---------------------------------------------------------------------------
# lowering passes
# ---------------------------------------------------------------------------

def _emit_pipe_events(trace, events: List[Dict[str, Any]], tid_for) -> None:
    # eid -> (ts, tid) of the issue event, for flow-arrow endpoints
    issue_at: Dict[int, tuple] = {}
    for ev in trace.events:
        tid = tid_for(ev.label)
        if ev.kind == "issue":
            args: Dict[str, Any] = {"eid": ev.eid}
            if ev.sid >= 0:
                args["sid"] = ev.sid
            if ev.gid >= 0:
                args["gid"] = ev.gid
            if ev.bid >= 0:
                args["bid"] = ev.bid
            if ev.dep_n:
                args["ordinal"] = ev.dep_n
            events.append({"ph": "X", "pid": PID, "tid": tid,
                           "ts": ev.t0, "dur": 0, "cat": "issue",
                           "name": ev.tag and f"{ev.op}:{ev.tag}" or ev.op,
                           "args": args})
            issue_at[ev.eid] = (ev.t0, tid)
        elif ev.kind == "bubble":
            events.append({"ph": "X", "pid": PID, "tid": tid,
                           "ts": ev.t0, "dur": ev.t1 - ev.t0,
                           "cat": "bubble", "name": ev.tag or ev.op,
                           "args": {"eid": ev.eid}})
            issue_at[ev.eid] = (ev.t0, tid)
        elif ev.kind in ("mma", "tma"):
            cat = "wgmma" if ev.kind == "mma" else "tma"
            nm = ev.tag and f"{cat}:{ev.tag}" or ev.op
            args = {"eid": ev.eid, "cycles": ev.t1 - ev.t0}
            if ev.kind == "tma" and ev.fixed:
                args["fixed_cycles"] = ev.fixed
            if ev.sid >= 0:
                args["sid"] = ev.sid
            if ev.gid >= 0:
                args["gid"] = ev.gid
            events.append({"ph": "b", "pid": PID, "tid": tid, "ts": ev.t0,
                           "cat": cat, "id": ev.eid, "name": nm,
                           "args": args})
            events.append({"ph": "e", "pid": PID, "tid": tid, "ts": ev.t1,
                           "cat": cat, "id": ev.eid, "name": nm})
            src = issue_at.get(ev.src)
            if ev.src >= 0 and src is not None:
                s_ts, s_tid = src
                events.append({"ph": "s", "pid": PID, "tid": s_tid,
                               "ts": s_ts, "cat": "flow", "id": ev.eid,
                               "name": "launch"})
                events.append({"ph": "f", "pid": PID, "tid": tid,
                               "ts": ev.t0, "cat": "flow", "id": ev.eid,
                               "name": "launch", "bp": "e"})


def _counter(events, ts, name, key, value):
    events.append({"ph": "C", "pid": PID, "ts": ts, "name": name,
                   "args": {key: value}})


def _emit_counter_tracks(snk, events: List[Dict[str, Any]],
                         ring_track_limit: Optional[int]) -> None:
    for c, bw in snk.dram_bw_timeline():
        _counter(events, c, "DRAM bandwidth", "GB/s", round(bw, 2))
    for c, u in snk.dram_util_timeline():
        _counter(events, c, "DRAM util %", "%", _percent(u))
    for c, bw in snk.l2_bw_timeline():
        _counter(events, c, "L2 bandwidth", "GB/s", round(bw, 2))
    for c, r in snk.l2_hit_rate_timeline():
        _counter(events, c, "L2 hit %", "%", _percent(r))
    for c, u in snk.tc_util_timeline():
        _counter(events, c, "TensorCore busy %", "%", _percent(u))
    for c, n in snk.tma_inflight_timeline():
        _counter(events, c, "TMA in-flight lines", "lines", n)
    for c, n in zip(snk.cycles, snk.resident_ctas):
        _counter(events, c, "Resident CTAs", "ctas", n)
    for (cta, ring), series in sorted(snk.ring_occupancy.items()):
        if ring_track_limit is not None and cta >= ring_track_limit:
            continue
        nm = f"ring cta{cta}/{ring}"
        for c, depth in series:
            _counter(events, c, nm, "stages", depth)


def _emit_stall_tracks(trace, events: List[Dict[str, Any]],
                       window: int) -> None:
    from repro.obs.counters import role_stall_timelines

    for role, buckets in sorted(role_stall_timelines(
            trace, window=window).items()):
        for bucket, wins in sorted(buckets.items()):
            nm = f"stall {role}:{bucket}"
            for w0 in sorted(wins):
                _counter(events, w0, nm, "cycles", round(wins[w0], 1))
