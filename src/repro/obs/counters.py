"""Simulated performance-monitor counters (the NCU-style metrics surface).

The cycle engine's ``stats()`` are end-of-run totals and the event trace is
per-instruction — neither answers "what did DRAM bandwidth look like over
the kernel" the way Nsight-Compute / nvprof counter timelines do for real
GPUs (the Hopper microbenchmarking ground truth arrives exactly as such
counters).  :class:`CounterSink` fills that gap by *sampling* engine state
at N-cycle window boundaries:

  * the run loop checks one integer per iteration (``cycle >=
    sink.next_sample``) and calls :meth:`sample` at most once per crossed
    window boundary — the ~565k per-line cache events of a full launch are
    never touched individually, so counters stay cheap when on and one
    branch when off;
  * :meth:`sample` only *reads* engine state (cumulative stats counters,
    instantaneous queue depths) — it mutates nothing, which is what makes
    the sink bit-neutral by construction (``sim_cycles`` and ``stats()``
    identical with the sink on or off, enforced in
    ``tests/test_engine_equiv.py``).

Sampled series (cumulative unless noted):

  ``dram_bytes``, ``dram_busy``   — DRAM bytes served / channel-busy cycles
  ``l2_hits/misses/merges/requests`` — L2 slice counters (post-LRC)
  ``lrc_merged``                  — LRC duplicate-line merges
  ``tma_lines``                   — TMA lines issued across all SMs
  ``tma_inflight``                — instantaneous in-flight TMA lines
  ``resident_ctas``               — instantaneous resident CTA count
  ``tc_busy[sm]``                 — per-SM tensor-core busy cycles
  ``ring_occupancy[(cta, ring)]`` — instantaneous filled stages per declared
                                    ring buffer (kernel-IR ``rings`` metadata)

Windowed rates/utilizations are derived views over consecutive samples
(:meth:`dram_bw_timeline`, :meth:`l2_hit_rate_timeline`, ...).  Because the
event-driven scheduler jumps over quiet stretches, consecutive samples can
be *more* than ``window`` cycles apart; every derived rate therefore
normalizes by the measured interval, and the conservation invariants
(integral of a timeline == the engine total) hold exactly regardless of
sampling cadence — see ``tests/test_obs.py``.

Per-role stall-reason timelines are a different beast: they derive from the
recorded :class:`~repro.analysis.events.PipeEvent` trace (the stall
*attribution* of ``analysis.critical_path`` reused as a timeline source),
not from engine sampling — see :func:`role_stall_timelines`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.labels import role_of

DEFAULT_WINDOW = 256


class CounterSink:
    """Opt-in PM-counter sampler attached via ``Engine(counters=...)``."""

    def __init__(self, window: int = DEFAULT_WINDOW):
        if window <= 0:
            raise ValueError("counter window must be positive")
        self.window = window
        self.next_sample = 0          # engine loop: sample when cycle >= this
        self.machine = None           # GPUMachine, captured on first sample
        # parallel sample series (index-aligned with .cycles)
        self.cycles: List[int] = []
        self.dram_bytes: List[int] = []
        self.dram_busy: List[float] = []
        self.l2_hits: List[int] = []
        self.l2_misses: List[int] = []
        self.l2_merges: List[int] = []
        self.l2_requests: List[int] = []
        self.lrc_merged: List[int] = []
        self.tma_lines: List[int] = []
        self.tma_inflight: List[int] = []       # instantaneous
        self.resident_ctas: List[int] = []      # instantaneous
        self.tc_busy: Dict[int, List[int]] = {}
        # cumulative fault-injected extra cycles per category; empty lists
        # (and empty timelines) when the engine runs without a fault plan
        self.fault_injected: Dict[str, List[int]] = {}
        # (cta_idx, ring name) -> [(cycle, filled stages)], instantaneous
        self.ring_occupancy: Dict[Tuple[int, str], List[Tuple[int, int]]] = {}
        self.ring_depths: Dict[Tuple[int, str], int] = {}   # declared stages
        self.totals: Dict[str, float] = {}
        self._finished = False

    # ------------------------------------------------------------------
    # engine-facing hooks (reads only — bit-neutrality depends on it)
    def sample(self, cycle: int, eng) -> None:
        """Snapshot engine counters at ``cycle``; called by the run loop at
        window-boundary crossings and once more at run end."""
        if self.cycles and self.cycles[-1] == cycle:
            return                     # idempotent per cycle (finish overlap)
        self.next_sample = cycle - cycle % self.window + self.window
        if self.machine is None:
            self.machine = eng.cfg
        l2 = eng.l2.stats()
        self.cycles.append(cycle)
        self.dram_bytes.append(eng.dram.bytes_served)
        self.dram_busy.append(getattr(eng.dram, "busy_cycles", 0))
        self.l2_hits.append(l2.get("hits", 0))
        self.l2_misses.append(l2.get("misses", 0))
        self.l2_merges.append(l2.get("mshr_merges", 0))
        self.l2_requests.append(l2.get("requests", 0))
        self.lrc_merged.append(eng.lrc.merged)
        lines = inflight = ctas = 0
        for sm in eng.sms:
            tma = sm.tma
            lines += tma.lines_issued
            for job in tma.jobs:
                inflight += job["inflight"]
            ctas += len(sm.ctas)
            self.tc_busy.setdefault(sm.sm_id, []).append(sm.tc.busy_cycles)
            for cta in sm.ctas:
                rings = cta.trace.rings
                if not rings:
                    continue
                mb = cta.mbarrier
                rel = cta.stage_releases
                n_cons = cta.n_consumers
                for name, sids in rings.items():
                    depth = 0
                    for sid in sids:
                        depth += mb.get(sid, 0) - rel.get(sid, 0) // n_cons
                    key = (cta.idx, name)
                    self.ring_depths.setdefault(key, len(sids))
                    self.ring_occupancy.setdefault(key, []).append(
                        (cycle, depth))
        self.tma_lines.append(lines)
        self.tma_inflight.append(inflight)
        self.resident_ctas.append(ctas)
        fl = getattr(eng, "faults", None)
        if fl is not None:
            for cat, v in fl.injected.items():
                self.fault_injected.setdefault(cat, []).append(v)

    def finish(self, cycle: int, eng) -> None:
        """Final closing sample — run once by the engine before it returns
        ``stats()`` — plus the frozen conservation totals."""
        if self._finished:
            return
        self.sample(cycle, eng)
        self._finished = True
        self.totals = {
            "cycles": cycle,
            "dram_bytes": eng.dram.bytes_served,
            "tc_busy_cycles": sum(sm.tc.busy_cycles for sm in eng.sms),
            "tma_lines": sum(sm.tma.lines_issued for sm in eng.sms),
            "l2_hits": self.l2_hits[-1] if self.l2_hits else 0,
            "l2_misses": self.l2_misses[-1] if self.l2_misses else 0,
        }

    # ------------------------------------------------------------------
    # derived views
    def windows(self) -> List[Tuple[int, int]]:
        """Consecutive sample intervals ``[(c0, c1), ...]`` (may be wider
        than ``window`` where the event loop jumped quiet stretches)."""
        return [(a, b) for a, b in zip(self.cycles, self.cycles[1:]) if b > a]

    def _deltas(self, series: List[int]) -> List[Tuple[int, int, int]]:
        out = []
        for i in range(1, len(self.cycles)):
            c0, c1 = self.cycles[i - 1], self.cycles[i]
            if c1 > c0:
                out.append((c0, c1, series[i] - series[i - 1]))
        return out

    def dram_bytes_per_window(self) -> List[Tuple[int, int, int]]:
        """``[(c0, c1, bytes), ...]`` — integrates exactly to total DRAM
        bytes served (conservation invariant)."""
        return self._deltas(self.dram_bytes)

    def dram_bw_timeline(self) -> List[Tuple[int, float]]:
        """Achieved DRAM GB/s per window, stamped at the window end."""
        f = self.machine.freq_ghz if self.machine else 1.0
        return [(c1, db / (c1 - c0) * f)          # B/cycle * Gcycle/s = GB/s
                for c0, c1, db in self._deltas(self.dram_bytes)]

    def dram_util_timeline(self) -> List[Tuple[int, float]]:
        """Fraction of peak DRAM bandwidth achieved per window."""
        if self.machine is None:
            return []
        peak = self.machine.dram_bw_gbps
        return [(c, min(1.0, bw / peak)) for c, bw in self.dram_bw_timeline()]

    def l2_bw_timeline(self) -> List[Tuple[int, float]]:
        """Delivered L2 GB/s (post-LRC requests x line bytes) per window."""
        if self.machine is None:
            return []
        lb, f = self.machine.line_bytes, self.machine.freq_ghz
        return [(c1, dreq * lb / (c1 - c0) * f)
                for c0, c1, dreq in self._deltas(self.l2_requests)]

    def l2_hit_rate_timeline(self) -> List[Tuple[int, float]]:
        """L2 hit fraction per window: hits / (hits + misses + MSHR merges).
        Windows with no L2 activity are skipped."""
        out = []
        hs = self._deltas(self.l2_hits)
        ms = self._deltas(self.l2_misses)
        gs = self._deltas(self.l2_merges)
        for (c0, c1, h), (_, _, m), (_, _, g) in zip(hs, ms, gs):
            tot = h + m + g
            if tot > 0:
                out.append((c1, h / tot))
        return out

    def tma_inflight_timeline(self) -> List[Tuple[int, int]]:
        """Instantaneous in-flight TMA lines at each sample."""
        return list(zip(self.cycles, self.tma_inflight))

    def tc_busy_per_window(self, sm_id: Optional[int] = None
                           ) -> List[Tuple[int, int, int]]:
        """Tensor-core busy cycles per window for one SM (or summed over
        all).  Busy cycles are charged at WGMMA issue, so a window can show
        more busy than elapsed cycles when long ops start inside it; the
        series still integrates exactly to ``tc_busy_cycles``."""
        if sm_id is not None:
            return self._deltas(self.tc_busy[sm_id])
        summed = [sum(v[i] for v in self.tc_busy.values())
                  for i in range(len(self.cycles))]
        return self._deltas(summed)

    def tc_util_timeline(self, sm_id: Optional[int] = None
                         ) -> List[Tuple[int, float]]:
        n = 1 if sm_id is not None else max(1, len(self.tc_busy))
        return [(c1, busy / ((c1 - c0) * n))
                for c0, c1, busy in self.tc_busy_per_window(sm_id)]

    def ring_max_depths(self) -> Dict[Tuple[int, str], int]:
        """Peak sampled occupancy per (cta, ring) — must never exceed the
        declared stage count (``ring_depths``)."""
        return {k: max(d for _, d in v) if v else 0
                for k, v in self.ring_occupancy.items()}

    def fault_injection_timeline(self, cat: str
                                 ) -> List[Tuple[int, int, int]]:
        """``[(c0, c1, extra_cycles), ...]`` fault-injected latency per
        window for one category (``dram``/``l2``/``tma``/``completion``/
        ``compute``); integrates exactly to the session's injected total.
        Empty when the run had no fault plan attached."""
        series = self.fault_injected.get(cat)
        if not series:
            return []
        return self._deltas(series)

    def avg_resident_ctas(self) -> float:
        """Time-weighted average resident CTA count (occupancy numerator)."""
        num = den = 0
        for i in range(1, len(self.cycles)):
            dt = self.cycles[i] - self.cycles[i - 1]
            num += self.resident_ctas[i - 1] * dt
            den += dt
        return num / den if den else 0.0


# ---------------------------------------------------------------------------
# per-role stall-reason timelines (PipeEvent-derived, not engine-sampled)
# ---------------------------------------------------------------------------

def role_stall_timelines(trace, window: int = DEFAULT_WINDOW
                         ) -> Dict[str, Dict[str, Dict[int, float]]]:
    """Per-declared-role stall timelines: ``role -> bucket -> {window_start:
    cycles}``, derived from a recorded :class:`EventTracer` trace by reusing
    the dependency-DAG stall attribution as a timeline source.

    Bucket semantics match ``analysis.critical_path.attribute_stalls``
    exactly (the same 5 buckets, including transitive softmax-bubble
    exposure); per (label, bucket) the windowed values sum to the
    attribution totals (float apportionment across window boundaries)."""
    from repro.analysis import dag as dag_mod
    from repro.analysis.critical_path import stall_timeline

    dag = dag_mod.build(trace.events, trace.dispatch_parent)
    per_label = stall_timeline(dag, window=window)
    out: Dict[str, Dict[str, Dict[int, float]]] = {}
    for label, buckets in per_label.items():
        role = role_of(label)
        acc = out.setdefault(role, {})
        for bucket, wins in buckets.items():
            b = acc.setdefault(bucket, {})
            for w0, cyc in wins.items():
                b[w0] = b.get(w0, 0.0) + cyc
    return out
