"""Run provenance manifests — "who produced this number, where, and how".

Every artifact the simulator emits (``SimResult``, sweep cache files,
``report.save_json`` payloads, BENCH_engine.json rows, Perfetto traces)
gets stamped with a manifest so results stay attributable after the code
moves on:

  * **code**: git sha + dirty flag of the repo that ran;
  * **host**: platform/python fingerprint, hashed into ``host_id`` so perf
    gates can compare like-for-like hosts instead of absolute cycles/s;
  * **run**: machine/workload/kernel config hashes, scheduler, counter
    window, wall time, simulated cycles, events/s;
  * **wall_breakdown**: optional host-side per-subsystem wall split
    (cProfile tottime aggregated by top-level module — ``core.engine``,
    ``core.memory``, ``analysis``, ...), replacing one-off profiler runs
    as the backing for perf claims in docs/performance.md.

Manifests are plain JSON-serializable dicts (schema in
docs/observability.md); ``build_manifest`` fills what it can and omits
what it is not given, so cheap call sites stay cheap.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import subprocess
import sys
import time
from typing import Any, Dict, Optional

MANIFEST_VERSION = 1


def _hash(obj: Any) -> str:
    """Stable short hash of any JSON-serializable object."""
    blob = json.dumps(obj, sort_keys=True, default=str).encode()
    return hashlib.md5(blob).hexdigest()[:12]


def config_hash(obj: Any) -> str:
    """Short content hash of a config-ish object (dataclass, dict, ...)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _hash(dataclasses.asdict(obj))
    return _hash(obj)


_GIT_SHA_CACHE: Dict[Optional[str], str] = {}


def git_sha(root: Optional[str] = None) -> str:
    """Current git sha (12 chars, ``-dirty`` suffixed), or ``"unknown"``.
    Memoized per root — sweeps stamp hundreds of manifests per process and
    must not shell out to git for each one."""
    if root in _GIT_SHA_CACHE:
        return _GIT_SHA_CACHE[root]
    _GIT_SHA_CACHE[root] = sha = _git_sha_uncached(root)
    return sha


def _git_sha_uncached(root: Optional[str]) -> str:
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=root, stderr=subprocess.DEVNULL, text=True).strip()
        dirty = subprocess.run(
            ["git", "diff", "--quiet", "HEAD"],
            cwd=root, stderr=subprocess.DEVNULL).returncode != 0
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def host_info() -> Dict[str, str]:
    """The host attributes that matter for wall-clock comparability."""
    return {
        "system": platform.system(),
        "machine": platform.machine(),
        "processor": platform.processor(),
        "python": "%d.%d.%d" % sys.version_info[:3],
        "impl": platform.python_implementation(),
    }


def host_fingerprint(info: Optional[Dict[str, str]] = None) -> str:
    """Short hash identifying a host class for like-for-like perf gates.
    Two runs with equal fingerprints may be compared on cycles/s; runs
    with different fingerprints may not (see bench_engine smoke gate)."""
    return _hash(info if info is not None else host_info())


def build_manifest(*,
                   machine: Any = None,
                   workload: Any = None,
                   kernel: Optional[str] = None,
                   tiling: Any = None,
                   scheduler: Optional[str] = None,
                   fidelity: Optional[str] = None,
                   mem_fidelity: Optional[str] = None,
                   counter_window: Optional[int] = None,
                   wall_s: Optional[float] = None,
                   sim_cycles: Optional[int] = None,
                   events_popped: Optional[int] = None,
                   wall_breakdown: Optional[Dict[str, float]] = None,
                   faults: Any = None,
                   extra: Optional[Dict[str, Any]] = None
                   ) -> Dict[str, Any]:
    """Assemble a provenance manifest dict.  All sections are optional;
    unknown/ungiven fields are simply omitted (cheap call sites stay
    cheap — git is shelled out to once per call, everything else is
    in-process)."""
    hi = host_info()
    m: Dict[str, Any] = {
        "manifest_version": MANIFEST_VERSION,
        "git_sha": git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "host": hi,
        "host_id": host_fingerprint(hi),
    }
    if machine is not None:
        m["machine_hash"] = config_hash(machine)
        m["machine_name"] = getattr(machine, "name", None)
    if workload is not None:
        m["workload_hash"] = config_hash(workload)
    if kernel is not None:
        m["kernel"] = kernel
    if tiling is not None:
        m["tiling_hash"] = config_hash(tiling)
    if scheduler is not None:
        m["scheduler"] = scheduler
    if fidelity is not None:
        m["fidelity"] = fidelity
    if mem_fidelity is not None:
        # tile-mode rows time differently from line-exact rows: the smoke
        # gate must never compare cycles/s across memory fidelities
        m["mem_fidelity"] = mem_fidelity
    if counter_window is not None:
        m["counter_window"] = counter_window
    if wall_s is not None:
        m["wall_s"] = round(wall_s, 6)
    if sim_cycles is not None:
        m["sim_cycles"] = sim_cycles
        if wall_s:
            m["cycles_per_s"] = round(sim_cycles / wall_s, 1)
    if events_popped is not None:
        m["events_popped"] = events_popped
        if wall_s:
            m["events_per_s"] = round(events_popped / wall_s, 1)
    if wall_breakdown is not None:
        m["wall_breakdown"] = wall_breakdown
    if faults is not None:
        # fault/variability provenance: a perturbed number is only
        # attributable if the artifact says which plan + seed produced it
        fd = faults.to_dict() if hasattr(faults, "to_dict") else dict(faults)
        m["fault_plan_hash"] = _hash(fd)
        m["fault_seed"] = fd.get("seed")
        m["fault_plan"] = {"name": fd.get("name") or None,
                           "kinds": sorted({p.get("kind") for p in
                                            fd.get("perturbations", ())})}
    if extra:
        m.update(extra)
    return m


def same_host(a: Optional[Dict[str, Any]], b: Optional[Dict[str, Any]]
              ) -> bool:
    """True when two manifests come from the same host class (their
    wall-clock rates are comparable)."""
    if not a or not b:
        return False
    ha, hb = a.get("host_id"), b.get("host_id")
    return ha is not None and ha == hb


# ---------------------------------------------------------------------------
# host-side subsystem wall breakdown (cProfile-backed)
# ---------------------------------------------------------------------------

_SUBSYSTEMS = ("core/engine", "core/memory", "core/kprog", "core",
               "analysis", "obs", "benchmarks")


def _subsystem_of(filename: str) -> str:
    norm = filename.replace("\\", "/")
    if "/repro/" in norm:
        tail = norm.split("/repro/", 1)[1]
        for sub in _SUBSYSTEMS:
            if tail.startswith(sub + "/") or tail == sub + ".py" or \
                    tail.startswith(sub + "."):
                return sub.replace("/", ".")
        return "repro.other"
    if "/benchmarks/" in norm or norm.startswith("benchmarks/"):
        return "benchmarks"
    return "stdlib/other"


def subsystem_wall_breakdown(fn, *args, **kwargs):
    """Run ``fn(*args, **kwargs)`` under cProfile and return
    ``(result, {subsystem: wall-second tottime})`` — self-time aggregated
    by module path so "X% of wall is the memory hierarchy" style claims
    are reproducible with one call instead of a hand-driven profiler
    session (docs/performance.md cites this)."""
    import cProfile
    import pstats

    prof = cProfile.Profile()
    result = prof.runcall(fn, *args, **kwargs)
    stats = pstats.Stats(prof)
    out: Dict[str, float] = {}
    for (filename, _lineno, _name), row in stats.stats.items():
        tottime = row[2]
        if tottime <= 0:
            continue
        key = _subsystem_of(filename)
        out[key] = out.get(key, 0.0) + tottime
    return result, {k: round(v, 4) for k, v in
                    sorted(out.items(), key=lambda kv: -kv[1])}
