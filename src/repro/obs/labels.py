"""The ``cta{i}/{role}`` warpgroup-label convention — single source of truth.

Every subsystem that names a warpgroup lane (engine thread labels, gantt
tags, stall attribution, counter tracks, Perfetto thread names) goes through
these helpers.  Before this module, ``core.gantt`` and
``analysis.critical_path`` each re-parsed the convention by hand and could
drift independently; now both call here.

Vocabulary
  * **label** — ``cta{idx}/{role-instance}``, e.g. ``cta3/consumer1``
    (``cta3/wg0`` for traces built outside the kernel IR);
  * **role instance** — the per-warpgroup name, e.g. ``consumer1``;
  * **role** — the declared role with the instance index stripped, e.g.
    ``consumer`` (aggregation key for cross-CTA views);
  * **gantt tag** — ``{lane}:{label}:{op-tag}``, e.g.
    ``mma:cta0/consumer1:QK`` (the legacy flat-interval encoding).

This module is deliberately import-free so anything (``core``, ``analysis``,
``obs``) can depend on it without cycles.
"""
from __future__ import annotations

from typing import Optional, Tuple

LABEL_SEP = "/"
TAG_SEP = ":"


def make_label(cta_idx: int, role_instance: str) -> str:
    """Compose the canonical warpgroup label: ``cta{idx}/{role_instance}``."""
    return f"cta{cta_idx}{LABEL_SEP}{role_instance}"


def split_label(label: str) -> Tuple[Optional[int], str]:
    """``"cta3/consumer1"`` -> ``(3, "consumer1")``.

    The CTA index is ``None`` when the label carries no parsable ``cta{i}``
    prefix (hand-built traces are allowed to use free-form labels)."""
    head, sep, inst = label.rpartition(LABEL_SEP)
    if not sep:
        return None, label
    if head.startswith("cta"):
        try:
            return int(head[3:]), inst
        except ValueError:
            pass
    return None, inst


def cta_of(label: str) -> Optional[int]:
    """CTA launch index behind a label, or ``None``."""
    return split_label(label)[0]


def role_of(label: str) -> str:
    """Declared role behind a warpgroup label: ``cta3/consumer1`` ->
    ``consumer``.  Labels carry the kernel IR's role-instance names
    (``producer``, ``consumer0``, ...; positional ``wg0`` only for traces
    built outside the IR); the cta prefix and instance index are stripped
    so per-role views aggregate across instances and CTAs."""
    inst = split_label(label)[1]
    stripped = inst.rstrip("0123456789")
    return stripped if stripped else inst


def split_gantt_tag(tag: str) -> Tuple[str, str, str]:
    """``"mma:cta0/consumer1:QK"`` -> ``("mma", "cta0/consumer1", "QK")``.
    Missing parts come back as ``""``."""
    lane, _, rest = tag.partition(TAG_SEP)
    label, _, op_tag = rest.partition(TAG_SEP)
    return lane, label, op_tag


def lane_of(tag: str) -> str:
    """Lane (``tma`` / ``mma`` / ``bubble``) of a gantt tag."""
    return split_gantt_tag(tag)[0]


def label_of(tag: str) -> str:
    """Warpgroup label embedded in a gantt tag."""
    return split_gantt_tag(tag)[1]
