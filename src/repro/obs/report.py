"""NCU-style per-kernel section report.

Condenses one simulated launch into the summary table Nsight Compute
prints for a real kernel: speed-of-light percentages (achieved vs. peak
DRAM / L2 / tensor-core rates), occupancy, and the 5-bucket stall
breakdown.  This is the calibration surface for machine presets (ROADMAP
item 5): the Hopper microbenchmarking papers publish exactly these
achieved rates, so a preset is validated by diffing this table against
their measurements.

Peak references come from the :class:`GPUMachine`:

  * DRAM — ``dram_bw_gbps`` (aggregate HBM);
  * L2 — ``l2_slices * line_bytes`` bytes/cycle (every slice serving one
    line per cycle, the engine's structural ceiling);
  * tensor core — ``peak_tflops_fp16`` scaled by achieved busy fraction.

``build_report`` returns a plain JSON-serializable dict (so it can ride in
``report.save_json`` artifacts); ``render_report`` pretty-prints it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional


def _pct(x: float) -> float:
    return round(100.0 * x, 2)


def build_report(result, cfg, *, workload=None,
                 manifest: Optional[dict] = None) -> Dict[str, Any]:
    """Build the section report for one :class:`SimResult` against machine
    ``cfg``.  Uses ``result.counters`` (occupancy, ring depths) and
    ``result.trace`` (stall buckets) when the run recorded them; sections
    without their source simply don't appear."""
    seconds = result.latency_us * 1e-6
    dram_gbps = result.dram_bytes / seconds / 1e9 if seconds else 0.0
    l2_gbps = result.l2_delivered_bytes / seconds / 1e9 if seconds else 0.0
    l2_peak_gbps = cfg.l2_slices * cfg.line_bytes * cfg.freq_ghz
    tc_frac = result.tc_util

    rep: Dict[str, Any] = {
        "kernel": result.kernel,
        "fidelity": result.fidelity,
        "cycles": result.cycles,
        "latency_us": round(result.latency_us, 3),
        "deadlocked": result.deadlocked,
        "launch": {
            "ctas_total": result.n_ctas_total,
            "ctas_simulated": result.n_ctas_simulated,
            "waves": round(result.n_ctas_total /
                           (cfg.num_sms * cfg.occupancy_limit), 3),
        },
        "speed_of_light": {
            "dram_gbps": round(dram_gbps, 1),
            "dram_peak_gbps": cfg.dram_bw_gbps,
            "dram_pct": _pct(dram_gbps / cfg.dram_bw_gbps),
            "l2_gbps": round(l2_gbps, 1),
            "l2_peak_gbps": round(l2_peak_gbps, 1),
            "l2_pct": _pct(l2_gbps / l2_peak_gbps),
            "tensorcore_pct": _pct(tc_frac),
            "tensorcore_tflops": round(tc_frac * cfg.peak_tflops_fp16, 1),
            "sol_pct": _pct(max(dram_gbps / cfg.dram_bw_gbps,
                                l2_gbps / l2_peak_gbps, tc_frac)),
        },
        "memory": {
            "dram_bytes": result.dram_bytes,
            "l2_demand_bytes": result.l2_bytes,
            "l2_delivered_bytes": result.l2_delivered_bytes,
            "l2_stats": result.l2_stats,
        },
    }
    if workload is not None:
        rep["workload"] = getattr(workload, "name", str(workload))

    dl = getattr(result, "deadlock_info", None)
    if dl is not None:
        rep["deadlock"] = dl     # analysis.hazards.explain_deadlock dict
    hz = getattr(result, "hazards", None)
    if hz:
        rep["hazards"] = [i.render() for i in hz]
    fs = getattr(result, "fault_stats", None)
    if fs is not None:
        rep["faults"] = fs       # faults.FaultSession.stats() dict
    if getattr(result, "aborted", False):
        ai = getattr(result, "abort_info", None) or {}
        rep["abort"] = {k: ai.get(k) for k in
                        ("reason", "cycle", "wall_s", "launched", "retired",
                         "in_flight", "pending")}

    snk = getattr(result, "counters", None)
    if snk is not None and snk.cycles:
        occ_limit = cfg.num_sms * cfg.occupancy_limit
        avg = snk.avg_resident_ctas()
        rep["occupancy"] = {
            "avg_resident_ctas": round(avg, 2),
            "limit_ctas": occ_limit,
            "pct": _pct(avg / occ_limit),
        }
        maxd = snk.ring_max_depths()
        if maxd:
            rep["rings"] = {
                f"cta{cta}/{ring}": {
                    "peak_depth": depth,
                    "declared": snk.ring_depths[(cta, ring)],
                }
                for (cta, ring), depth in sorted(maxd.items())[:16]
            }
        if snk.tma_inflight:
            rep["tma"] = {
                "peak_inflight_lines": max(snk.tma_inflight),
                "limit_per_job": cfg.tma_max_inflight_lines,
            }

    trace = getattr(result, "trace", None)
    if trace is not None and trace.events:
        from repro.analysis import dag as dag_mod
        from repro.analysis.critical_path import attribute_stalls

        sr = attribute_stalls(dag_mod.build(trace.events,
                                            trace.dispatch_parent))
        totals = sr.totals()
        stalled = sum(totals.values())
        rep["stalls"] = {
            "total_stall_cycles": round(stalled, 1),
            "buckets": {k: round(v, 1) for k, v in totals.items()},
            "by_role": {role: {k: round(v, 1) for k, v in b.items()}
                        for role, b in sr.by_role().items()},
        }

    if manifest is not None:
        rep["manifest"] = manifest
    return rep


def render_report(rep: Dict[str, Any]) -> str:
    """Pretty-print a section report (the NCU table look)."""
    L = []
    hdr = f"{rep['kernel']}  [{rep['fidelity']}]"
    L.append(hdr)
    L.append("=" * len(hdr))
    L.append(f"  cycles {rep['cycles']:>12.0f}    latency"
             f" {rep['latency_us']:.1f} us"
             + ("    ** DEADLOCKED **" if rep.get("deadlocked") else "")
             + ("    ** ABORTED **" if rep.get("abort") else ""))
    if rep.get("abort"):
        ab = rep["abort"]
        L.append(f"  watchdog abort ({ab.get('reason')}): cycle"
                 f" {ab.get('cycle')}, {ab.get('retired')}/"
                 f"{ab.get('launched')} CTAs retired,"
                 f" {ab.get('pending')} pending,"
                 f" {ab.get('wall_s')} s wall")
    if rep.get("deadlock"):
        from repro.analysis.hazards import render_deadlock
        L.extend(render_deadlock(rep["deadlock"]))
    for line in rep.get("hazards", ()):
        L.append(f"  sanitizer: {line}")
    la = rep["launch"]
    L.append(f"  ctas {la['ctas_total']} (simulated"
             f" {la['ctas_simulated']}), {la['waves']} waves")
    sol = rep["speed_of_light"]
    L.append("  -- speed of light " + "-" * 40)
    L.append(f"  DRAM        {sol['dram_gbps']:>8.1f} /"
             f" {sol['dram_peak_gbps']:>7.1f} GB/s   {sol['dram_pct']:>6.2f} %")
    L.append(f"  L2          {sol['l2_gbps']:>8.1f} /"
             f" {sol['l2_peak_gbps']:>7.1f} GB/s   {sol['l2_pct']:>6.2f} %")
    L.append(f"  TensorCore  {sol['tensorcore_tflops']:>8.1f} TFLOP/s"
             f"             {sol['tensorcore_pct']:>6.2f} %")
    L.append(f"  SOL                                      "
             f"{sol['sol_pct']:>6.2f} %")
    if "occupancy" in rep:
        oc = rep["occupancy"]
        L.append(f"  occupancy   {oc['avg_resident_ctas']:>8.2f} /"
                 f" {oc['limit_ctas']:>4d} CTAs     {oc['pct']:>6.2f} %")
    if "tma" in rep:
        L.append(f"  TMA peak in-flight {rep['tma']['peak_inflight_lines']}"
                 f" lines (limit {rep['tma']['limit_per_job']}/job)")
    if "rings" in rep:
        L.append("  -- ring occupancy (peak/declared) " + "-" * 24)
        for name, r in rep["rings"].items():
            L.append(f"  {name:<20s} {r['peak_depth']}/{r['declared']}")
    if "stalls" in rep:
        st = rep["stalls"]
        L.append("  -- stall breakdown " + "-" * 39)
        for k, v in sorted(st["buckets"].items(), key=lambda kv: -kv[1]):
            L.append(f"  {k:<18s} {v:>12.1f} cycles")
    if "faults" in rep:
        f = rep["faults"]
        plan = f.get("plan", {})
        L.append("  -- fault injection " + "-" * 39)
        L.append(f"  plan {plan.get('name') or '<unnamed>'}"
                 f"  seed {plan.get('seed')}"
                 + ("  (identity)" if plan.get("identity") else ""))
        ev = f.get("injection_events", {})
        for k, v in sorted(f.get("injected_cycles", {}).items(),
                           key=lambda kv: -kv[1]):
            if v:
                L.append(f"  {k:<12s} +{v:>10d} cycles over"
                         f" {ev.get(k, 0)} events")
        if f.get("offline_sms"):
            L.append(f"  offline SMs: {f['offline_sms']}")
    man = rep.get("manifest") or {}
    if man:
        L.append(f"  [{man.get('git_sha', '?')} @"
                 f" {man.get('host_id', '?')}"
                 f" {man.get('scheduler', '')}]".rstrip())
    return "\n".join(L)
