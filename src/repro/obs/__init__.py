"""Observability layer: simulated PM counters, Perfetto export, manifests.

``obs`` is the metrics surface between the cycle engine and the outside
world — what Nsight Compute is to a real GPU:

  * :mod:`repro.obs.labels` — the ``cta{i}/{role}`` label convention,
    single source of truth for everything that names a warpgroup lane;
  * :mod:`repro.obs.counters` — opt-in :class:`CounterSink` sampling
    NCU-style windowed timelines off the engine, bit-neutral by design;
  * :mod:`repro.obs.trace_export` — PipeEvent trace + counter tracks
    lowered to Chrome ``trace_event`` JSON (ui.perfetto.dev);
  * :mod:`repro.obs.report` — NCU-style per-kernel section report
    (speed-of-light %, occupancy, stall buckets);
  * :mod:`repro.obs.manifest` — run provenance stamped onto every
    simulate/sweep/bench artifact.

See docs/observability.md for the walkthrough.
"""
from repro.obs.counters import CounterSink, role_stall_timelines
from repro.obs.manifest import (build_manifest, config_hash, git_sha,
                                host_fingerprint, host_info, same_host,
                                subsystem_wall_breakdown)
from repro.obs.report import build_report, render_report
from repro.obs.trace_export import build_trace, export_trace

__all__ = [
    "CounterSink", "role_stall_timelines",
    "build_manifest", "config_hash", "git_sha", "host_fingerprint",
    "host_info", "same_host", "subsystem_wall_breakdown",
    "build_report", "render_report",
    "build_trace", "export_trace",
]
