"""Sharding rules: parameter/optimizer/activation partitioning.

Strategy (DESIGN.md §7):
  * ``model`` axis = tensor parallel (heads, d_ff, vocab, experts-or-ff);
  * ``data`` (+ ``pod`` when present) = batch DP **and** FSDP-style sharding
    of parameters/optimizer state (per-layer all-gather inside the scan);
  * MoE expert weights choose EP (experts over 'model') when E divides the
    axis, else TP within experts — switchable for §Perf experiments;
  * KV caches shard heads over 'model'; long-context (batch 1) caches shard
    the *sequence* over 'data' (SP) and merge with distributed LSE.

Rules are path-pattern based over the param pytree; stacked scan layers
(leading L dim) are detected by path prefix and get PartitionSpec(None, ...).
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


_STACKED = re.compile(r"(^|/)(blocks|groups|prologue|enc_blocks|dec_blocks)(/|$)")
_EXTRA_STACK = re.compile(r"(^|/)groups(/|$)")   # zamba groups: (G, M, ...)


def _rule(path: str, ndim: int, cfg, moe_sharding: str) -> Tuple:
    """Spec for the unstacked (per-layer) leaf."""
    f = "F"   # placeholder: fsdp axes
    t = "model"

    def last(name):
        return path.endswith(name) or path.endswith(name + "/w")

    # --- MoE expert tensors (E, d, ff) / (E, ff, d)
    if "/moe/" in path:
        if last("router"):
            return (f, None)
        ep = moe_sharding == "ep"
        if last("wg") or last("wu"):
            return (t, f, None) if ep else (None, f, t)
        if last("wd"):
            return (t, None, f) if ep else (None, t, f)

    # --- attention projections
    if re.search(r"/(attn|xattn)/w[qkv]/w$", path):
        return (f, t)
    if re.search(r"/(attn|xattn)/w[qkv]/b$", path):
        return (t,)
    if re.search(r"/(attn|xattn)/wo/w$", path):
        return (t, f)
    if re.search(r"/(attn|xattn)/wo/b$", path):
        return (None,)

    # --- dense mlp
    if re.search(r"/mlp/w[gu]/w$", path):
        return (f, t)
    if re.search(r"/mlp/w[gu]/b$", path):
        return (t,)
    if re.search(r"/mlp/wd/w$", path):
        return (t, f)
    if re.search(r"/mlp/wd/b$", path):
        return (None,)

    # --- mamba
    if "/mamba/" in path:
        if last("in_proj"):
            return (f, t)
        if last("out_proj"):
            return (t, f)
        if path.endswith("conv_w"):
            return (None, t)
        if path.endswith(("conv_b",)):
            return (t,)
        if path.endswith(("A_log", "D", "dt_bias")):
            return (None,)
        if "/norm/" in path:
            return (t,)

    # --- rwkv
    if "/tmix/" in path or "/cmix/" in path:
        if re.search(r"/w[rkvg]/w$", path) or last("wk") or last("wr"):
            return (f, t)
        if re.search(r"/(wo|wv)/w$", path):
            return (t, f)
        if path.endswith("maa_w1") or path.endswith("decay_w1"):
            return (f, None)
        if path.endswith("maa_w2"):
            return (None, None, f)
        if path.endswith("decay_w2"):
            return (None, f)
        if path.endswith("u"):
            return (t, None)
        if path.endswith("decay"):
            return (f,)
        if "/ln_x/" in path:
            return (t,)
        return tuple(None for _ in range(ndim))

    # --- embeddings / unembed
    if path.endswith("emb/table"):
        return (t, f)
    if path.endswith("unembed/w"):
        return (f, t)
    if path.endswith("unembed/b"):
        return (t,)
    if path.endswith("pos_dec"):
        return (None, f)

    # norms and anything residual-dim shaped: replicate
    return tuple(None for _ in range(ndim))


def _materialize(spec_tuple, mesh: Mesh):
    fa = fsdp_axes(mesh)
    out = []
    for s in spec_tuple:
        if s == "F":
            out.append(fa if len(fa) > 1 else fa[0])
        else:
            out.append(s)
    return P(*out)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims whose size doesn't divide the axis product
    (e.g. odd vocabularies, KV-head counts below the TP degree)."""
    out = []
    for i, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        out.append(s if shape[i] % prod == 0 else None)
    return P(*out)


def param_specs(cfg, params_shape, mesh: Mesh, *, moe_sharding: str = "auto"):
    """PartitionSpec pytree matching ``params_shape`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    if moe_sharding == "auto":
        msize = mesh.shape["model"]
        moe_sharding = "ep" if (cfg.num_experts and
                                cfg.num_experts % msize == 0) else "tp"

    def spec(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        ndim = len(leaf.shape)
        stack = 0
        if _STACKED.search(path):
            stack = 1
            if _EXTRA_STACK.search(path) and "shared_attn" not in path:
                stack = 2
        base = _rule(path, ndim - stack, cfg, moe_sharding)
        full = (None,) * stack + tuple(base)
        if len(full) != ndim:   # fallback: replicate
            full = (None,) * ndim
        return sanitize_spec(_materialize(full, mesh), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(cfg, params_shape, mesh, **kw):
    specs = param_specs(cfg, params_shape, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache / activation specs
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh) -> P:
    return P(fsdp_axes(mesh) if len(fsdp_axes(mesh)) > 1 else fsdp_axes(mesh)[0])


def data_specs(cfg, shape_kind: str, mesh: Mesh, *, batch: int):
    """PartitionSpecs for the input batch dict."""
    dp = fsdp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b = P(dp, None)
    specs = {"tokens": b}
    if shape_kind == "train":
        specs["labels"] = b
    if cfg.family == "vlm":
        specs["embeds"] = P(dp, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(dp, None, None)
    return specs


def cache_specs(cfg, mesh: Mesh, *, batch: int, seq_shard: bool = False):
    """Sharding for decode caches. seq_shard=True -> SP layout for batch=1
    long-context: KV sequence over 'data', heads over 'model'.

    When KV heads don't divide the TP degree (GQA kv=8 on model=16), the
    cache *sequence* shards over 'model' instead — the distributed
    flash-decode LSE merge makes this exact (models/attention.py)."""
    dp = fsdp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    seq_ax, batch_ax = (dpa, None) if seq_shard else (None, dpa)
    heads_divisible = cfg.num_kv_heads % mesh.shape["model"] == 0
    if heads_divisible:
        kv = P(None, batch_ax, seq_ax, "model", None)   # (L,B,S,H,D)
    elif seq_shard:
        kv = P(None, batch_ax, (dpa, "model") if not isinstance(dpa, tuple)
               else tuple(dpa) + ("model",), None, None)
    else:
        kv = P(None, batch_ax, "model", None, None)     # seq over model
    scalar = P()
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": kv, "v": kv, "idx": scalar}
    if cfg.family == "ssm":
        return {"layers": {"S": P(None, batch_ax, "model", None, None),
                           "x_att": P(None, batch_ax, None),
                           "x_cmix": P(None, batch_ax, None)},
                "idx": scalar}
    if cfg.family == "hybrid":
        st = {"conv": P(None, batch_ax, None, "model"),
              "ssm": P(None, batch_ax, "model", None, None)}
        st2 = {"conv": P(None, None, batch_ax, None, "model"),
               "ssm": P(None, None, batch_ax, "model", None, None)}
        return {"prologue": st, "groups": st2,
                "attn_k": kv, "attn_v": kv, "idx": scalar}
    if cfg.family == "encdec":
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "idx": scalar}
    raise ValueError(cfg.family)
