"""Activation-sharding context: model code calls ``constrain(x, kind)`` at
block boundaries; inside an ``activation_sharding(...)`` scope this becomes
``with_sharding_constraint`` (critical: keeps scan-saved residuals sharded —
without it XLA can replicate the remat carries and blow per-device HBM by
the DP degree), outside it is a no-op (single-device tests).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@dataclass(frozen=True)
class ActivationSpecs:
    specs: Dict[str, P] = field(default_factory=dict)

    def get(self, kind: str) -> Optional[P]:
        return self.specs.get(kind)


def current() -> Optional[ActivationSpecs]:
    return getattr(_STATE, "specs", None)


@contextmanager
def activation_sharding(**kinds):
    """activation_sharding(residual=P('data','model',None), ...)"""
    prev = current()
    _STATE.specs = ActivationSpecs(dict(kinds))
    try:
        yield
    finally:
        _STATE.specs = prev


def constrain(x, kind: str = "residual"):
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.get(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
