"""Serve steps: prefill and single-token decode, jit-ready.

``make_serve_step`` returns the decode_step lowered in the dry-run for the
``decode_32k`` / ``long_500k`` cells: one new token per sequence against a
resident KV cache (or SSM state), greedy-sampled.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import api


def make_prefill_step(cfg, *, max_seq: int, remat: str = "full",
                      attn_chunk: int = 512, cast_params: str = "none",
                      attn_pv_bf16: bool = False):
    def prefill_step(params, batch):
        if cast_params != "none":
            cdt = jnp.dtype(cast_params)
            params = jax.tree.map(
                lambda p: p.astype(cdt)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        attn_fn = None
        if attn_chunk != 512 or attn_pv_bf16:
            from repro.models.attention import flash_ref
            attn_fn = partial(flash_ref, chunk=attn_chunk,
                              pv_bf16=attn_pv_bf16)
        hidden, cache = api.prefill(cfg, params, batch, max_seq=max_seq,
                                    remat=remat, attn_fn=attn_fn)
        logits = api.unembed(cfg, params, hidden[:, -1:])
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, cache, tokens):
        """tokens: (B, 1) -> (next_token (B,1), new_cache)."""
        logits, cache = api.decode(cfg, params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step
