"""Batched serving engine: continuous-batching-lite over jitted prefill /
decode steps, with straggler deadlines driven by the SimFA performance
predictor (the paper's model as a production feature — DESIGN.md §4).

Slots hold independent requests; finished slots are refilled from the queue
without stopping the decode loop. Designed so the decode step is the same
function the dry-run lowers for the decode_32k/long_500k cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class StragglerPolicy:
    """Deadline-based step watchdog: expected step time comes from the
    SimFA predictor; steps slower than ``factor`` x expectation are counted
    and surfaced (on real fleets: triggers re-dispatch / hot-spare swap)."""
    expected_step_s: float = 0.1
    factor: float = 5.0
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        slow = dt > self.factor * self.expected_step_s
        if slow:
            self.slow_steps += 1
        return slow

    @classmethod
    def from_samples(cls, samples, *, percentile: float = 0.99,
                     factor_floor: float = 1.5) -> "StragglerPolicy":
        """Calibrate from a sampled step-time distribution instead of a
        hand-picked factor — the fleet-serving consumer of
        ``repro.faults.sensitivity.step_time_samples``: Monte-Carlo the
        decode step under a seeded variability plan, then set the deadline
        where the *modeled* tail ends so only genuinely anomalous hosts
        trip it.  Expectation is the sample median; the factor is the
        p-``percentile``/median ratio (floored at ``factor_floor`` so a
        tight distribution still tolerates scheduler noise)."""
        xs = sorted(float(s) for s in samples)
        if not xs:
            return cls()
        med = xs[len(xs) // 2]
        hi = xs[min(len(xs) - 1, int(percentile * (len(xs) - 1)))]
        factor = max(factor_floor, hi / med if med > 0 else factor_floor)
        return cls(expected_step_s=med, factor=factor)


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 256,
                 straggler: Optional[StragglerPolicy] = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.straggler = straggler or StragglerPolicy()
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = api.init_cache(cfg, slots, max_seq,
                                    dtype=jnp.dtype(cfg.compute_dtype))
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        from repro.serve.decode import make_serve_step
        self._decode = jax.jit(make_serve_step(cfg))
        self.steps = 0
        self.prompt_len: Optional[int] = None

    def submit(self, req: Request):
        # fixed prompt length per engine instance (scalar cache index);
        # production variant: per-slot index vector + length masking
        if self.prompt_len is None:
            self.prompt_len = len(req.prompt)
        assert len(req.prompt) == self.prompt_len, \
            "engine instance serves fixed-length prompts"
        self.queue.append(req)

    # --------------------------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Single-request prefill into the shared cache (slot-batched)."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        _, cache1 = api.prefill(self.cfg, self.params, {"tokens": toks},
                                max_seq=self.max_seq)
        slots = self.slots

        def splice(big, small):
            if small.ndim == 0:
                return big            # scalar index: set below
            for ax in range(big.ndim):
                if (big.shape[ax] == slots and small.shape[ax] == 1
                        and big.shape[:ax] == small.shape[:ax]
                        and big.shape[ax + 1:] == small.shape[ax + 1:]):
                    sl = [slice(None)] * big.ndim
                    sl[ax] = slice(slot, slot + 1)
                    return big.at[tuple(sl)].set(small.astype(big.dtype))
            return big

        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.cache["idx"] = cache1["idx"]
        self.tokens = self.tokens.at[slot, 0].set(int(req.prompt[-1]))

    def step(self):
        """One engine tick: refill empty slots, run one decode step."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(i, req)
                self.active[i] = req
        if all(r is None for r in self.active):
            return False
        t0 = time.time()
        next_tok, self.cache = self._decode(self.params, self.cache, self.tokens)
        next_tok.block_until_ready()
        self.straggler.observe(time.time() - t0)
        self.tokens = next_tok
        self.steps += 1
        toks = np.asarray(next_tok)[:, 0]
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(toks[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        while (self.queue or any(self.active)) and self.steps < max_steps:
            self.step()
        return finished
