"""Production serving launcher: continuous-batching engine over the same
decode step the dry-run lowers, with SimFA-predicted straggler deadlines.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.configs.llama3 import AttnWorkload
from repro.core import analytical
from repro.core.machine import H800, TPU_V5E
from repro.core.tpu.analytical import analyze_tpu
from repro.models import api
from repro.serve.engine import Request, ServeEngine, StragglerPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init(cfg, jax.random.PRNGKey(0))

    w = AttnWorkload(name="decode", B=args.slots, L=1, S=args.max_seq,
                     H_kv=cfg.num_kv_heads or 4, G=cfg.q_group_size or 1,
                     D=cfg.head_dim)
    pred = analyze_tpu(w, TPU_V5E)
    print(f"SimFA-TPU decode prediction: {pred.latency*1e6:.1f} us "
          f"({pred.bottleneck}-bound)")
    # GPU-mode counterpart through the split-KV FlashDecoding kernel's
    # traffic hooks (the serving workload the cycle engine can now see)
    gpu = analytical.analyze(w, H800, kernel="splitkv_decode")
    print(f"SimFA-H800 split-KV decode prediction: {gpu.latency*1e6:.1f} us "
          f"({gpu.bottleneck}-bound, "
          f"{gpu.dram_bytes/1e6:.2f} MB DRAM/step)")

    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=args.max_seq,
                      straggler=StragglerPolicy(expected_step_s=0.5, factor=10))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               args.prompt_len),
                           max_new=args.max_new))
    t0 = time.time()
    while eng.queue or any(eng.active):
        eng.step()
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"served {args.requests} requests / {toks} tokens in "
          f"{eng.steps} steps, {dt:.2f}s; "
          f"{eng.straggler.slow_steps} straggler step(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
