import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower+compile ONE cell with plan overrides and
print the three roofline terms (before/after comparisons drive the
hypothesis->change->measure loop recorded in EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.perf --arch grok-1-314b \
        --shape train_4k --set cast_params=bfloat16 --set grad_acc_sharded=1

Appends each measurement to results/perf_log.json.
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.launch import specs as specs_lib
from repro.launch.dryrun import run_cell

LOG = Path("results/perf_log.json")


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="CellPlan override")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    shape = SHAPES[args.shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
    mesh_kind = ("multi_pod_2x16x16" if args.mesh == "multi"
                 else "single_pod_16x16")

    overrides = {}
    for s in args.set:
        k, v = s.split("=", 1)
        overrides[k] = parse_val(v)

    base_plan = specs_lib.plan_cell(cfg, shape, mesh)
    plan = dataclasses.replace(base_plan, **overrides)
    print(f"plan: {plan}")

    t0 = time.time()
    rec = run_cell(cfg, shape, mesh, mesh_kind, plan=plan)
    r = roofline.analyze_record(f"{args.arch}|{args.shape}|{mesh_kind}", rec)
    out = {
        "tag": args.tag or ",".join(args.set) or "baseline",
        "arch": args.arch, "shape": args.shape, "mesh": mesh_kind,
        "overrides": overrides,
        "t_compute_s": r["t_compute_s"],
        "t_memory_s": r["t_memory_s"],
        "t_collective_s": r["t_collective_s"],
        "bottleneck": r["bottleneck"],
        "useful_flops_ratio": r["useful_flops_ratio"],
        "roofline_fraction": r["roofline_fraction"],
        "peak_gb": rec["mem"]["peak_bytes"] / 1e9,
        "collectives": rec["collectives"],
        "wall_s": round(time.time() - t0, 1),
    }
    print(json.dumps(out, indent=1))
    log = json.loads(LOG.read_text()) if LOG.exists() else []
    log.append(out)
    LOG.write_text(json.dumps(log, indent=1))


if __name__ == "__main__":
    main()
