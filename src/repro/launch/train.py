"""Production training launcher: mesh construction from real devices,
sharded state init, checkpoint/restart, straggler watchdog with a
SimFA-predicted step deadline, preemption-signal save.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --dp 1 --tp 1 --batch 8 --seq 64 --steps 20

On a fleet this runs under one process per host (jax.distributed); the
mesh axes here are the single-host equivalent of the production
("pod","data","model") mesh the dry-run validates at 512 chips.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.manager import CheckpointManager
from repro.configs import registry
from repro.configs.llama3 import AttnWorkload
from repro.core.machine import TPU_V5E
from repro.core.tpu.analytical import analyze_tpu
from repro.data.synthetic import DataIterator
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd
from repro.serve.engine import StragglerPolicy
from repro.train import optimizer as opt
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-trainable)")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/train_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = args.dp * args.tp
    assert n_dev <= jax.device_count(), \
        f"need {n_dev} devices, have {jax.device_count()}"
    mesh = jax.make_mesh((args.dp, args.tp), ("data", "model"),
                         devices=jax.devices()[:n_dev])
    print(f"mesh {mesh.shape} on {n_dev} device(s); arch {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params analytic)")

    run = trainer.RunConfig(
        microbatches=args.microbatches, remat=args.remat,
        opt=opt.OptConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps, schedule=cfg.lr_schedule))

    state = trainer.init_state(cfg, run, jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, state.params, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    state = trainer.TrainState(
        params=jax.tree.map(jax.device_put, state.params, pshard),
        opt_state=opt.OptState(
            m=jax.tree.map(jax.device_put, state.opt_state.m, pshard),
            v=jax.tree.map(jax.device_put, state.opt_state.v, pshard),
            step=state.opt_state.step),
        ef_error=state.ef_error)

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        start, state = ckpt.restore_latest(state)
        print(f"[restart] resumed from step {start}")

    # straggler deadline from the paper's performance model: decode/train
    # attention time predicted for the target hardware, scaled by a
    # calibration factor measured on the first step
    w = AttnWorkload(name="train", B=args.batch, L=args.seq, S=args.seq,
                     H_kv=cfg.num_kv_heads or 4, G=cfg.q_group_size or 1,
                     D=cfg.head_dim, causal=True)
    pred = analyze_tpu(w, TPU_V5E)
    watchdog = StragglerPolicy(expected_step_s=1.0, factor=5.0)
    print(f"SimFA-TPU attention prediction: {pred.latency*1e6:.1f} us/layer "
          f"({pred.bottleneck}-bound) — watchdog calibrates off step 1")

    step_fn = jax.jit(trainer.make_train_step(cfg, run, grad_specs=pspecs),
                      donate_argnums=0)
    data = DataIterator(cfg, batch=args.batch, seq=args.seq, start_step=start)

    # preemption: SIGTERM triggers a final checkpoint before exit
    preempted = {"flag": False}
    signal.signal(signal.SIGTERM, lambda *a: preempted.__setitem__("flag", True))

    dp = shd.batch_spec(mesh)
    with mesh:
        for step in range(start, args.steps):
            batch = {k: jax.device_put(jnp.asarray(v), NamedSharding(mesh, dp))
                     for k, v in next(data).items()}
            t0 = time.time()
            with pctx.activation_sharding(residual=P("data", None, None)):
                state, metrics = step_fn(state, batch)
            jax.tree.leaves(metrics)[0].block_until_ready()
            dt = time.time() - t0
            if step == start:
                watchdog.expected_step_s = dt      # calibrate
            slow = watchdog.observe(dt)
            print(f"step {step+1:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + ("  [STRAGGLER]" if slow else ""), flush=True)
            if (step + 1) % args.ckpt_every == 0 or preempted["flag"]:
                ckpt.save(step + 1, state)
            if preempted["flag"]:
                ckpt.wait()
                print("[preempt] checkpoint published; exiting")
                return 17
    ckpt.wait()
    ckpt.save(args.steps, state, blocking=True)
    print(f"done: {args.steps} steps; {watchdog.slow_steps} straggler "
          f"step(s); checkpoints in {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
