"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """Version-compat shim: ``jax.sharding.AxisType`` (and the
    ``axis_types=`` kwarg of ``jax.make_mesh``) only exist on newer jax;
    older releases treat every axis as Auto already, so the kwarg is simply
    omitted there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod:  2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / local runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))


def make_local_mesh(model: int = 1):
    """Whatever this host has, folded into (data, model)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))
