"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod:  2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / local runs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Whatever this host has, folded into (data, model)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))
