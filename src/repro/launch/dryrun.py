import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out results/dryrun.json]

Results are written incrementally (resumable; --force recomputes)."""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.utils.hlo import collective_bytes, hlo_cost, xla_cost_analysis

OUT_DEFAULT = "results/dryrun.json"


def run_cell(cfg, shape, mesh, mesh_kind: str, plan=None) -> dict:
    t0 = time.time()
    cell = specs_lib.build_cell(cfg, shape, mesh, plan=plan)
    with mesh:
        jitted = jax.jit(
            cell["fn"],
            in_shardings=cell["in_shardings"],
            donate_argnums=cell["donate"] or None)
        lowered = jitted.lower(*cell["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # XLA's cost_analysis counts while-loop (scan) bodies ONCE; hlo_cost
    # multiplies by trip counts — use it for the roofline terms and keep
    # the raw XLA numbers for reference (utils/hlo.py docstring).
    hc = hlo_cost(hlo_text)
    plan = cell["plan"]
    n_dev = mesh.size
    rec = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_kind,
        "status": "ok",
        "devices": n_dev,
        "kind": cell["meta"]["kind"],
        "tokens": cell["meta"]["tokens"],
        "plan": {k: getattr(plan, k) for k in
                 ("microbatches", "remat", "moe_impl", "moe_sharding",
                  "opt_dtype", "grad_dtype", "seq_shard_acts",
                  "seq_shard_cache")},
        "flops_per_device": hc["flops"],
        "bytes_per_device": hc["bytes"],
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll.get("total", 0.0),
        "collectives": {k: v for k, v in coll.items() if not k.startswith("count")},
        "collective_counts": {k: v for k, v in coll.items() if k.startswith("count")},
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes + mem.temp_size_in_bytes),
        },
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--include-extra", action="store_true",
                    help="also run llama3-8b (not part of the 40 cells)")
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", mesh_lib.make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       mesh_lib.make_production_mesh(multi_pod=True)))

    cells = list(registry.cells(include_extra=args.include_extra))
    for cfg, shape, supported, why in cells:
        if args.arch and cfg.name != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        for mesh_kind, mesh in meshes:
            key = f"{cfg.name}|{shape.name}|{mesh_kind}"
            if key in results and results[key].get("status") == "ok" \
                    and not args.force:
                print(f"[skip cached] {key}")
                continue
            if not supported:
                results[key] = {"arch": cfg.name, "shape": shape.name,
                                "mesh": mesh_kind, "status": "skipped",
                                "reason": why}
                print(f"[skip arch] {key}: {why}")
                out_path.write_text(json.dumps(results, indent=1))
                continue
            print(f"[lower+compile] {key} ...", flush=True)
            try:
                rec = run_cell(cfg, shape, mesh, mesh_kind)
                peak = rec["mem"]["peak_bytes"]
                print(f"  ok: flops/dev={rec['flops_per_device']:.3g} "
                      f"peak={peak/1e9:.2f}GB coll={rec['collective_bytes_per_device']:.3g}B "
                      f"compile={rec['t_compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = {"arch": cfg.name, "shape": shape.name,
                       "mesh": mesh_kind, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"  ERROR {type(e).__name__}: {str(e)[:300]}", flush=True)
            results[key] = rec
            out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    print(f"done: {n_ok} ok, {n_err} errors, {n_skip} skipped -> {out_path}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
