"""Per-cell planning: input ShapeDtypeStructs, shardings, and step functions
for every (arch x shape x mesh) dry-run cell.

``plan_cell`` applies the memory napkin math (16 GiB/chip budget) to choose
microbatch count, optimizer-state dtype, and activation sequence-sharding;
§Perf overrides land in PERF_OVERRIDES so the hillclimbed plans are explicit
and reproducible.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd
from repro.serve import decode as serve_decode
from repro.train import optimizer as opt
from repro.train import trainer

HBM_BUDGET = 14.5e9          # leave headroom under 16 GiB/chip


@dataclass(frozen=True)
class CellPlan:
    microbatches: int = 1
    remat: str = "full"
    moe_impl: str = "einsum"
    moe_sharding: str = "auto"
    opt_dtype: str = "float32"
    grad_dtype: str = "float32"
    seq_shard_acts: bool = False
    seq_shard_cache: bool = False      # SP for long-context decode
    loss_chunk: int = 2048
    # §Perf knobs (enabled per-cell through PERF_OVERRIDES; the defaults
    # are the paper-faithful baseline)
    cast_params: str = "none"          # bf16 fwd/bwd params -> bf16 FSDP AG
    grad_acc_sharded: bool = False     # reduce-scatter grads onto FSDP shards
    attn_chunk: int = 512              # flash KV chunk size
    attn_pv_bf16: bool = False         # FA3-style P tile FP32->bf16 for P@V
    moe_token_local: bool = False      # pin MoE dispatch buffers to token
                                       # sharding (stops expert replication)
    notes: str = ""


# §Perf hillclimb results land here: (arch, shape) -> overrides. Applied
# only when REPRO_PERF=1 so the default dry-run measures the paper-faithful
# baseline; `REPRO_PERF=1 python -m repro.launch.dryrun --out
# results/dryrun_perf.json` measures the optimized plans (EXPERIMENTS.md
# §Perf records both).
PERF_OVERRIDES: Dict[Tuple[str, str, str], Dict[str, Any]] = {
    # A4: mb=16 triggered pathological per-mb collectives (B_local=1 ->
    # partitioner replication); mb=8 cuts collective 502->74s, peak 38->17.5.
    # The multi-pod mesh has 32-way FSDP (local batch 8), so its microbatch
    # count halves again to keep B_local_mb >= 2.
    ("grok-1-314b", "train_4k", "16x16"): {"microbatches": 8},
    ("grok-1-314b", "train_4k", "2x16x16"): {"microbatches": 4},
    # B1: halve the FSDP re-gather & weight-grad reduce passes
    ("command-r-plus-104b", "train_4k", "16x16"): {"microbatches": 2},
    ("command-r-plus-104b", "train_4k", "2x16x16"): {"microbatches": 2},
    # C1+C2+C4: sequence-parallel prefill + 4x flash chunk + FA3 P-tile cast
    ("command-r-plus-104b", "prefill_32k", "16x16"): {
        "seq_shard_acts": True, "attn_chunk": 2048, "attn_pv_bf16": True},
    ("command-r-plus-104b", "prefill_32k", "2x16x16"): {
        "seq_shard_acts": True, "attn_chunk": 2048, "attn_pv_bf16": True},
}


def plan_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> CellPlan:
    n_dev = mesh.size
    fsdp = 1
    for a in shd.fsdp_axes(mesh):
        fsdp *= mesh.shape[a]
    tp = mesh.shape["model"]
    plan = CellPlan()
    if shape.kind == "train":
        n_params = cfg.param_count()
        # params fp32 + grads fp32 + adam m/v
        opt_dtype = "float32"
        per_param = 4 + 4 + 8
        if n_params * per_param / n_dev > 0.7 * HBM_BUDGET:
            opt_dtype = "bfloat16"
            per_param = 4 + 4 + 4
        fixed = n_params * per_param / n_dev
        # sequence-parallel residual stream by default: scan-saved remat
        # carries shard over ('model') too (measured 77GB -> 5.6GB on olmo)
        seq_shard = True
        # ssm/hybrid token-mixers need the whole sequence per layer (token
        # shift / conv / scan): intra-layer activations only shard over DP,
        # and run several fp32 passes -> much larger per-token constant
        if cfg.family in ("ssm", "hybrid"):
            tokens_local = shape.tokens / fsdp
            act_per_tok = cfg.d_model * 4 * 10
        else:
            tokens_local = shape.tokens / fsdp / tp
            act_per_tok = cfg.d_model * 2 * (cfg.num_layers + 8) * 3
        budget = max(HBM_BUDGET - fixed, 1e9)
        mb = 1
        while mb < 64 and tokens_local / mb * act_per_tok > budget:
            mb *= 2
        mb = min(mb, int(max(1, shape.global_batch // fsdp)))
        grad_dtype = "float32"
        if n_params * (per_param + 8) / n_dev > HBM_BUDGET:
            grad_dtype = "bfloat16"   # accumulate grads in bf16 (giant MoE)
        plan = dataclasses.replace(
            plan, microbatches=mb, opt_dtype=opt_dtype, grad_dtype=grad_dtype,
            seq_shard_acts=seq_shard)
    elif shape.kind == "decode" and shape.global_batch < fsdp:
        # batch can't fill the data axis -> shard the KV sequence instead
        plan = dataclasses.replace(plan, seq_shard_cache=True)
    if os.environ.get("REPRO_PERF") == "1":
        key = (cfg.name, shape.name, "x".join(map(str, mesh.devices.shape)))
        if key in PERF_OVERRIDES:
            plan = dataclasses.replace(plan, **PERF_OVERRIDES[key])
    return plan


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, kind: str):
    B, S = shape.global_batch, shape.seq_len
    s_tok = S
    out = {}
    if cfg.family == "vlm":
        s_tok = S - cfg.frontend_len
        out["embeds"] = _sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    out["tokens"] = _sds((B, s_tok), jnp.int32)
    if kind == "train":
        out["labels"] = _sds((B, s_tok), jnp.int32)
    return out


def _to_struct(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _cast_float(tree, dtype):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
        tree)


def _with_activation_ctx(fn, plan: CellPlan, mesh: Mesh, cfg=None):
    dp = shd.fsdp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    residual = P(dpa, "model" if plan.seq_shard_acts else None, None)
    residual_dec = P(None if plan.seq_shard_cache else dpa, None, None)
    kinds = dict(residual=residual, residual_dec=residual_dec)
    if plan.moe_token_local:
        # dispatched expert buffers (n_groups, E, cap, d): groups carry the
        # flattened token dim -> same axes the residual tokens shard over
        tok = (tuple(dp) + ("model",)) if plan.seq_shard_acts else dpa
        kinds["moe_tokens"] = P(tok, None, None, None)
    if cfg is not None and cfg.num_kv_heads:
        heads_div = cfg.num_kv_heads % mesh.shape["model"] == 0
        if heads_div:
            # collected prefill KV: (B, S, Hkv, D) heads over model
            kinds["kv_collect"] = P(dpa, None, "model", None)
        else:
            # seq over model; decode scores stay sharded on S -> psum stats
            kinds["kv_collect"] = P(dpa, "model", None, None)
            kinds["scores_dec"] = P(
                None if plan.seq_shard_cache else dpa, None, None, None, "model")

    def wrapped(*args):
        with pctx.activation_sharding(**kinds):
            return fn(*args)

    return wrapped


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               plan: Optional[CellPlan] = None):
    """Returns dict(fn, args (ShapeDtypeStructs), in_shardings,
    out_shardings, donate, meta) ready for jit().lower()."""
    plan = plan or plan_cell(cfg, shape, mesh)
    key = jax.random.PRNGKey(0)

    param_struct = jax.eval_shape(lambda: api.init(cfg, key))
    pspecs = shd.param_specs(cfg, param_struct, mesh,
                             moe_sharding=plan.moe_sharding)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    dspec = shd.data_specs(cfg, shape.kind, mesh, batch=shape.global_batch)
    bstruct = batch_struct(cfg, shape, kind=shape.kind)
    bshard = {k: NamedSharding(
        mesh, shd.sanitize_spec(dspec[k], bstruct[k].shape, mesh))
        for k in bstruct}

    if shape.kind == "train":
        run = trainer.RunConfig(
            microbatches=plan.microbatches, remat=plan.remat,
            moe_impl=plan.moe_impl, loss_chunk=plan.loss_chunk,
            grad_dtype=plan.grad_dtype, cast_params=plan.cast_params,
            attn_chunk=plan.attn_chunk, attn_pv_bf16=plan.attn_pv_bf16,
            opt=opt.OptConfig())
        step = _with_activation_ctx(
            trainer.make_train_step(
                cfg, run, grad_specs=pspecs if plan.grad_acc_sharded else None),
            plan, mesh, cfg)
        opt_dt = jnp.dtype(plan.opt_dtype)
        m_struct = _cast_float(param_struct, opt_dt)
        state_struct = trainer.TrainState(
            params=param_struct,
            opt_state=opt.OptState(m=m_struct, v=m_struct,
                                   step=_sds((), jnp.int32)),
            ef_error=None)
        state_shard = trainer.TrainState(
            params=pshard,
            opt_state=opt.OptState(m=pshard, v=pshard,
                                   step=NamedSharding(mesh, P())),
            ef_error=None)
        return dict(
            fn=step, args=(state_struct, bstruct),
            in_shardings=(state_shard, bshard),
            donate=(0,), plan=plan,
            meta=dict(kind="train", tokens=shape.tokens))

    serve_params = _cast_float(param_struct, jnp.bfloat16)

    if shape.kind == "prefill":
        step = _with_activation_ctx(
            serve_decode.make_prefill_step(
                cfg, max_seq=shape.seq_len, remat=plan.remat,
                attn_chunk=plan.attn_chunk, cast_params=plan.cast_params,
                attn_pv_bf16=plan.attn_pv_bf16),
            plan, mesh, cfg)
        return dict(
            fn=step, args=(serve_params, bstruct),
            in_shardings=(pshard, bshard),
            donate=(), plan=plan,
            meta=dict(kind="prefill", tokens=shape.tokens))

    # decode: one new token against a full cache
    B, S = shape.global_batch, shape.seq_len
    cache_struct = jax.eval_shape(
        lambda: api.init_cache(cfg, B, S, dtype=jnp.bfloat16))
    cspec = shd.cache_specs(cfg, mesh, batch=B, seq_shard=plan.seq_shard_cache)
    cspec = jax.tree.map(
        lambda s, st: shd.sanitize_spec(s, st.shape, mesh),
        cspec, cache_struct, is_leaf=lambda x: isinstance(x, P))
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                          is_leaf=lambda x: isinstance(x, P))
    tok_struct = _sds((B, 1), jnp.int32)
    tok_shard = NamedSharding(
        mesh, P(None) if plan.seq_shard_cache else shd.batch_spec(mesh))
    step = _with_activation_ctx(serve_decode.make_serve_step(cfg), plan, mesh, cfg)
    return dict(
        fn=step, args=(serve_params, cache_struct, tok_struct),
        in_shardings=(pshard, cshard, tok_shard),
        donate=(1,), plan=plan,
        meta=dict(kind="decode", tokens=B))
