"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch x shape x mesh) record in ``results/dryrun.json``:

    compute    = HLO_FLOPs_per_device  / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device  / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / ICI_link_bandwidth

(``cost_analysis()`` on a partitioned module is already per-device, so the
"/ chips" in the prompt formulas is folded in.) The dominant term is the
bottleneck; ``MODEL_FLOPS`` (6*N*D train, 2*N*D prefill, 2*N_active*B
decode) over total HLO FLOPs measures how much compiled compute is useful
(remat/dup waste shows up here); ``roofline_fraction`` = ideal compute time
of the useful FLOPs / dominant term — the score §Perf drives up.

    PYTHONPATH=src python -m repro.launch.roofline [--json results/dryrun.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import registry

# TPU v5e hardware constants (prompt-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def model_flops_total(arch: str, kind: str, tokens: int) -> float:
    """Useful model FLOPs for the whole step (all chips)."""
    cfg = registry.get(arch)
    n_active = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n_active * tokens
    # forward-only: 2*N per token (prefill tokens = B*S; decode tokens = B)
    return 2.0 * n_active * tokens


def analyze_record(key: str, rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    n_dev = rec["devices"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    dominant = terms[bottleneck]

    m_flops = model_flops_total(arch, rec["kind"], rec["tokens"])
    hlo_total = rec["flops_per_device"] * n_dev
    useful_ratio = m_flops / hlo_total if hlo_total else 0.0
    t_useful = (m_flops / n_dev) / PEAK_FLOPS
    frac = t_useful / dominant if dominant else 0.0

    suggest = {
        "compute": ("reduce non-useful FLOPs (remat policy, fused loss, "
                    "bf16 compute) — compute-bound is the good case"),
        "memory": ("raise arithmetic intensity: larger fused blocks, "
                   "bf16 activations/optimizer, avoid HBM round-trips "
                   "between layers"),
        "collective": ("reshard to cut all-gather/reduce-scatter volume: "
                       "different TP/FSDP split, overlap collectives with "
                       "compute, gradient-accumulation deferred psum"),
    }[bottleneck]
    return {
        "key": key, "arch": arch, "shape": shape, "mesh": mesh,
        "devices": n_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": m_flops,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": frac,
        "peak_bytes": rec["mem"]["peak_bytes"],
        "suggestion": suggest,
    }


def analyze_all(dryrun_json: Path) -> Dict[str, dict]:
    data = json.loads(Path(dryrun_json).read_text())
    out = {}
    for key, rec in data.items():
        r = analyze_record(key, rec)
        if r:
            out[key] = r
    return out


def to_markdown(rows: Dict[str, dict], mesh: str = "single_pod_16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "useful/HLO | roofline frac |")
    sep = "|---|---|---|---|---|---|---|---|"
    lines = [hdr, sep]
    for r in sorted(rows.values(), key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--mesh", default="single_pod_16x16")
    args = ap.parse_args()
    rows = analyze_all(Path(args.json))
    Path(args.out).write_text(json.dumps(rows, indent=1))
    print(to_markdown(rows, args.mesh))
    worst = sorted((r for r in rows.values() if r["mesh"] == args.mesh),
                   key=lambda r: r["roofline_fraction"])
    print("\nworst roofline fractions:")
    for r in worst[:5]:
        print(f"  {r['arch']}|{r['shape']}: {r['roofline_fraction']:.3f} "
              f"({r['bottleneck']}-bound) -> {r['suggestion']}")
    coll = sorted((r for r in rows.values() if r["mesh"] == args.mesh),
                  key=lambda r: -(r["t_collective_s"]
                                  / max(max(r["t_compute_s"], r["t_memory_s"]), 1e-12)))
    print("\nmost collective-bound:")
    for r in coll[:5]:
        ratio = r["t_collective_s"] / max(max(r["t_compute_s"], r["t_memory_s"]), 1e-12)
        print(f"  {r['arch']}|{r['shape']}: coll/max(other)={ratio:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
