"""High-level Sim-FA driver: simulate one attention-kernel launch.

The kernel program is resolved through the kernel registry
(``repro.core.kprog``): ``kernel="fa3"`` (default, the paper's ping-pong
FA3), ``"fa3_cooperative"``, ``"fa2"`` (non-specialized ablation baseline)
or ``"splitkv_decode"`` (FlashDecoding-style serving workload) — or any
externally registered :class:`~repro.core.kprog.ir.KernelSpec`.

Fidelity modes (§2.3: cycle simulation is prohibitively slow on large
workloads, so a corrected analytical model substitutes — we make the
substitution structured instead of ad hoc):

  * ``full``          — every CTA on every SM, line-exact memory.
  * ``tile``          — every CTA on every SM, tile-granular memory
    (``Engine(mem_fidelity="tile")``): traffic counters byte-identical to
    ``full``, cycles within the docs/fidelity.md error bound, ~10x faster.
    Requires the L2 request coalescer (``lrc_enabled`` machines).
  * ``hierarchical``  — simulate ``n_sub`` SMs (memory system scaled
    proportionally) for two waves; total latency composes the measured
    first-wave latency with the measured marginal (steady-state) wave cost
    times the remaining wave count. Traffic scales with the CTA ratio.
  * ``auto``          — precedence ``full`` -> ``tile`` -> ``hierarchical``:
    full when the launch fits ``FULL_CTA_LIMIT``, tile while it fits
    ``TILE_CTA_LIMIT`` (the ~10x engine speedup buys that headroom at
    bounded cycle error), hierarchical beyond that.  An *explicit*
    fidelity is always respected — no silent re-selection on large
    launches.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Union

from repro.configs.llama3 import AttnWorkload
from repro.core import analytical
from repro.core.engine import Engine
from repro.core.kprog import registry as kernel_registry
from repro.core.kprog.ir import KernelSpec
from repro.core.machine import GPUMachine
from repro.obs.counters import CounterSink
from repro.obs.manifest import build_manifest

FULL_CTA_LIMIT = 600
# the tile engine is ~10x faster than line-exact on the same launch
# (docs/fidelity.md), so auto keeps cycle simulation ~10x longer before
# falling back to the hierarchical wave model
TILE_CTA_LIMIT = 6000

FIDELITIES = ("auto", "full", "tile", "hierarchical")


@dataclass
class SimResult:
    latency_us: float
    cycles: float
    fidelity: str
    n_ctas_total: int
    n_ctas_simulated: int
    tc_util: float
    l2_bytes: float            # demand traffic issued toward L2 (pre-LRC,
                               # what Eq. 2 models), extrapolated
    l2_delivered_bytes: float  # post-LRC requests that reached the L2
    dram_bytes: float          # extrapolated DRAM traffic
    l2_stats: dict
    deadlocked: bool
    kernel: str = "fa3"
    gantt: Optional[list] = None
    trace: Optional[object] = None   # analysis.events.EventTracer of the
                                     # (first) simulated engine run
    counters: Optional[object] = None  # obs.counters.CounterSink of the
                                       # (first) simulated engine run
    manifest: Optional[dict] = None    # obs.manifest provenance stamp
    deadlock_info: Optional[dict] = None  # analysis.hazards.explain_deadlock
                                          # snapshot when deadlocked
    hazards: Optional[list] = None     # analysis.hazards.HazardIssue list
                                       # when the engine ran sanitize=True
    aborted: bool = False              # watchdog tripped mid-run; cycles /
                                       # traffic below are the salvaged
                                       # partial run, not a completed launch
    abort_info: Optional[dict] = None  # faults.watchdog.salvage snapshot
    fault_stats: Optional[dict] = None  # faults.FaultSession.stats() when a
                                        # fault plan was attached
    mem_fidelity: str = "line"  # engine memory model that produced the run
                                # ("line" exact / "tile" bulk transactions)


def _run(cfg, ctas, tmaps, n_sms, mem_scale, record_gantt=False,
         engine_opts=None, counters=None):
    eng = Engine(cfg, n_sms=n_sms, mem_scale=mem_scale,
                 record_gantt=record_gantt, counters=counters,
                 **(engine_opts or {}))
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    return eng, st


def simulate_fa3(w: AttnWorkload, cfg: GPUMachine,
                 tiling=None, fidelity: str = "auto",
                 n_sub: int = 8, record_gantt: bool = False,
                 record_events: bool = False,
                 record_counters: bool = False,
                 counter_window: int = 256,
                 engine_opts: Optional[dict] = None,
                 kernel: Union[str, KernelSpec] = "fa3",
                 faults=None, watchdog=None) -> SimResult:
    """Simulate one kernel launch (name kept for history; ``kernel=``
    dispatches through the registry, defaulting to the FA3 ping-pong the
    driver originally hardcoded).  ``tiling=None`` takes the spec's
    default tiling.  ``engine_opts`` forwards to :class:`Engine` — e.g.
    ``{"scheduler": "waiter"}`` to pin a fallback scheduler.

    ``record_counters=True`` attaches an :class:`obs.counters.CounterSink`
    (windowed PM-counter timelines on ``SimResult.counters``) to the first
    simulated engine run; it is bit-neutral — cycles and stats do not
    change.  Every result carries an ``obs.manifest`` provenance stamp.

    ``faults=`` attaches a :class:`repro.faults.FaultPlan` (or its
    ``to_dict`` form) to every simulated engine run — identity plans are
    bit-exact, seeded plans reproducible.  ``watchdog=`` attaches a
    :class:`repro.faults.Watchdog` budget; on trip the result comes back
    with ``aborted=True`` and the salvaged partial state in
    ``abort_info`` instead of hanging."""
    spec = kernel_registry.get(kernel)
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                         f"got {fidelity!r}")
    if faults is not None or watchdog is not None:
        engine_opts = dict(engine_opts or {})
        if faults is not None:
            engine_opts.setdefault("faults", faults)
        if watchdog is not None:
            engine_opts.setdefault("watchdog", watchdog)
    tiling = tiling if tiling is not None else spec.default_tiling()
    # total CTA count is analytic; only the traces we will actually run are
    # materialized (hierarchical mode simulates the first two waves only)
    total = spec.total_ctas(w, tiling)
    if fidelity == "auto":
        # documented precedence: full -> tile -> hierarchical.  An explicit
        # fidelity never reaches this branch (no silent re-selection).
        # Machines without the L2 request coalescer never auto-select tile
        # (the tile front end refuses lrc_enabled=False — per-line request
        # flooding only exists at line-exact fidelity).
        if total <= FULL_CTA_LIMIT:
            fidelity = "full"
        elif total <= TILE_CTA_LIMIT and cfg.lrc_enabled:
            fidelity = "tile"
        else:
            fidelity = "hierarchical"
    if fidelity == "tile":
        # the tile tier is the full-launch engine with the tile-granular
        # memory model; an explicit engine_opts mem_fidelity wins
        engine_opts = dict(engine_opts or {})
        engine_opts.setdefault("mem_fidelity", "tile")
    cycle_exact = fidelity in ("full", "tile")
    need = total if cycle_exact else 2 * n_sub * cfg.occupancy_limit
    ctas, tmaps = spec.build(cfg, w, tiling=tiling,
                             max_ctas=min(total, need))
    record = record_gantt or record_events
    snk = CounterSink(window=counter_window) if record_counters else None
    t_wall = time.perf_counter()

    if cycle_exact:
        eng, st = _run(cfg, ctas, tmaps, cfg.num_sms, 1.0, record,
                       engine_opts, counters=snk)
        manifest = _manifest(cfg, w, spec, tiling, eng, fidelity, snk,
                             time.perf_counter() - t_wall, st["cycles"])
        return SimResult(
            latency_us=st["time_us"], cycles=st["cycles"], fidelity=fidelity,
            n_ctas_total=total, n_ctas_simulated=total,
            mem_fidelity=eng.mem_fidelity,
            tc_util=st["tc_util"],
            l2_bytes=st["tma_lines"] * cfg.line_bytes,
            l2_delivered_bytes=st["l2_req_bytes"],
            dram_bytes=st["dram_bytes"], l2_stats=st["l2"],
            deadlocked=eng.deadlocked, kernel=spec.name,
            gantt=eng.gantt() if record_gantt else None,
            trace=eng.tracer if record_events else None,
            counters=snk, manifest=manifest,
            deadlock_info=eng.deadlock_info,
            hazards=(eng.sanitizer.issues
                     if eng.sanitizer is not None else None),
            aborted=eng.aborted, abort_info=eng.abort_info,
            fault_stats=(eng.faults.stats()
                         if eng.faults is not None else None))

    # hierarchical: n_sub SMs stand in for the machine; two-wave composition
    per_wave_sub = n_sub * cfg.occupancy_limit
    scale = n_sub / cfg.num_sms
    one = ctas[:per_wave_sub]
    two = ctas[:2 * per_wave_sub]
    eng1, st1 = _run(cfg, one, tmaps, n_sub, scale, record, engine_opts,
                     counters=snk)
    if len(two) > len(one):
        eng2, st2 = _run(cfg, two, tmaps, n_sub, scale,
                         engine_opts=engine_opts)
        marginal = max(st2["cycles"] - st1["cycles"], 1)
    else:
        eng2, st2 = eng1, st1
        marginal = st1["cycles"]

    waves_total = total / (cfg.num_sms * cfg.occupancy_limit)
    extra_waves = max(0.0, waves_total - 1.0)
    cycles = st1["cycles"] + extra_waves * marginal
    # traffic extrapolation: simulated CTAs -> all CTAs
    traf_scale = total / len(two)
    manifest = _manifest(cfg, w, spec, tiling, eng1, "hierarchical", snk,
                         time.perf_counter() - t_wall, cycles)
    return SimResult(
        latency_us=cycles / (cfg.freq_ghz * 1e3), cycles=cycles,
        fidelity="hierarchical", n_ctas_total=total,
        n_ctas_simulated=len(two),
        mem_fidelity=eng1.mem_fidelity,
        tc_util=st2["tc_util"],
        l2_bytes=st2["tma_lines"] * cfg.line_bytes * traf_scale,
        l2_delivered_bytes=st2["l2_req_bytes"] * traf_scale,
        dram_bytes=st2["dram_bytes"] * traf_scale,
        l2_stats=st2["l2"], deadlocked=eng1.deadlocked or eng2.deadlocked,
        kernel=spec.name,
        gantt=eng1.gantt() if record_gantt else None,
        trace=eng1.tracer if record_events else None,
        counters=snk, manifest=manifest,
        deadlock_info=eng1.deadlock_info or eng2.deadlock_info,
        hazards=(eng1.sanitizer.issues
                 if eng1.sanitizer is not None else None),
        aborted=eng1.aborted or eng2.aborted,
        abort_info=eng1.abort_info or eng2.abort_info,
        fault_stats=(eng1.faults.stats()
                     if eng1.faults is not None else None))


def _manifest(cfg, w, spec, tiling, eng, fidelity, snk, wall_s, cycles):
    return build_manifest(
        machine=cfg, workload=w, kernel=spec.name, tiling=tiling,
        scheduler=eng.scheduler, fidelity=fidelity,
        mem_fidelity=eng.mem_fidelity,
        counter_window=snk.window if snk is not None else None,
        wall_s=wall_s, sim_cycles=int(cycles),
        events_popped=eng.evq.popped,
        faults=eng.faults.plan if eng.faults is not None else None)


# preferred, kernel-neutral name
simulate = simulate_fa3


def validate_against_analytical(w: AttnWorkload, cfg: GPUMachine,
                                kernel: Union[str, KernelSpec] = "fa3",
                                **kw) -> dict:
    """Fig.-6 style row: simulated vs analytical latency + traffic, with
    the analytical side driven through the same kernel's traffic hooks."""
    spec = kernel_registry.get(kernel)
    sim = simulate_fa3(w, cfg, kernel=spec, **kw)
    tiling = kw.get("tiling")
    tiling = tiling if tiling is not None else spec.default_tiling()
    rep = analytical.analyze(w, cfg, kernel=spec, tiling=tiling)
    ape = abs(sim.latency_us - rep.latency * 1e6) / max(rep.latency * 1e6, 1e-9)
    return {
        "workload": w.name,
        "kernel": spec.name,
        "sim_us": sim.latency_us,
        "analytical_us": rep.latency * 1e6,
        "ape": ape,
        "sim_l2_bytes": sim.l2_bytes,
        "model_l2_bytes": rep.l2_bytes,
        "sim_dram_bytes": sim.dram_bytes,
        "model_dram_bytes": rep.dram_bytes,
        "bottleneck": rep.bottleneck,
        "fidelity": sim.fidelity,
        "tc_util": sim.tc_util,
    }
