"""Shared CUDA-core cost arithmetic for kernel programs (paper §5.2).

One home for the softmax-bubble formula so the trace generators and the
analytical model (Eq. ramp term) stop re-deriving it by copy-paste.
"""
from __future__ import annotations

import math

from repro.core.machine import GPUMachine

# The paper's reference FA3 tiling (§5.2): the analytical ramp term falls
# back to these when no tiling is given.
DEFAULT_T_M = 64
DEFAULT_T_N = 176


def softmax_bubble_cycles(cfg: GPUMachine, t_m: int, t_n: int, d: int) -> int:
    """§5.2 bubble arithmetic for one (T_M x T_N) tile per consumer WG.

    rowmax + exp + rowsum + fp16-convert + O-rescale; 956 cycles at the
    paper's 64x176xD128 reference point on H800 (the paper quotes ~988
    with a coarser rescale estimate — the golden cycle anchors are built
    on this formula).
    """
    elems = t_m * t_n
    rowmax = math.ceil(elems / cfg.fp32_ops_per_cycle)        # 88 @ 64x176
    expo = math.ceil(elems / cfg.mufu_ops_per_cycle)          # 704
    rowsum = math.ceil(elems / cfg.fp32_ops_per_cycle)        # 88
    cvt = math.ceil(elems / cfg.fp16_ops_per_cycle)           # 44
    rescale = math.ceil(t_m * d / cfg.fp16_ops_per_cycle)     # 32
    return rowmax + expo + rowsum + cvt + rescale             # = 956


def combine_cycles(cfg: GPUMachine, rows: int, d: int, n_parts: int) -> int:
    """Split-KV reduction epilogue: rescale + accumulate ``n_parts`` partial
    O tiles of (rows x d) fp32 plus the final normalization."""
    elems = rows * d
    rescale_acc = n_parts * math.ceil(2 * elems / cfg.fp32_ops_per_cycle)
    lse = n_parts * math.ceil(rows / cfg.mufu_ops_per_cycle)
    norm = math.ceil(elems / cfg.fp32_ops_per_cycle)
    return rescale_acc + lse + norm
