"""Static verifier for lowered kernel programs (the kprog legality oracle).

A :class:`~repro.core.kprog.ir.KernelSpec` that drops a ``release()``,
waits on a token before anything signals it, or over-subscribes a ring
used to surface only as the engine silently timing out into a bare
``deadlocked=True``.  This module decides legality *statically*, in
microseconds, from the lowered :class:`~repro.core.engine.CTATrace`
per-warpgroup instruction streams plus the IR's ring/token/barrier
metadata riders (``CTATrace.rings`` / ``tokens`` / ``acq_slots``) — the
oracle every registry kernel passes through at resolve time
(``registry.get``) and the pruning filter an autotuner needs to reject
illegal (roles, ring-depth, token-topology) candidates without simulating
each one into a deadlock.

Three checker families (catalogue in docs/verification.md):

  * **deadlock freedom** — an abstract concurrent execution of the CTA's
    warpgroups under maximal progress: async ops complete instantly (their
    completion is guaranteed in finite simulated time), so the only
    blocking conditions are the cross-warpgroup ones — mbarrier waits,
    ring ACQUIRE counting, named-barrier thresholds.  Because every engine
    condition is a monotone counter, the abstract execution quiesces at
    the counters' least fixed point: it completes **iff** the engine
    terminates.  On quiescence with live warpgroups, provider-less waits
    become ``unsatisfiable-wait`` findings and the remaining wait-for
    graph yields a minimal (BFS-shortest) witness cycle.
  * **protocol discipline** — per-warpgroup linear scans: every MB_WAIT
    has a reaching signaler (wait count vs. CTA-wide signal count per
    sid), ACQUIRE/load alternation per ring sid, wait/release pairing per
    consumer, WGMMA commit-group wait ≤ outstanding, TMA
    store → commit → wait ordering.
  * **hazards** — ring over-subscription (live acquires beyond ``stages``,
    with pre-wrap slot numbers as the aliasing witness), sid-space
    collisions between ring sids and the ``Q_READY_SID`` token range, and
    write-after-read races (a ring slot refilled or released out from
    under a reader: more releasing warpgroups than ``n_consumers``,
    releases without a matching wait).

The dynamic half — the same invariants cross-checked per event inside a
running engine — lives in :mod:`repro.analysis.hazards`
(``Engine(sanitize=True)``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core import isa
from repro.core.isa import Instr

ERROR = "error"
WARNING = "warning"

# finding codes (the checker catalogue)
DEADLOCK = "deadlock"
UNSATISFIABLE_WAIT = "unsatisfiable-wait"
BARRIER_UNDERFLOW = "barrier-underflow"
RING_OVERSUBSCRIPTION = "ring-oversubscription"
SID_COLLISION = "sid-collision"
UNGUARDED_LOAD = "unguarded-load"
RELEASE_WITHOUT_WAIT = "release-without-wait"
WAIT_RELEASE_MISMATCH = "wait-release-mismatch"
CONSUMER_MISMATCH = "consumer-mismatch"
COMMIT_PROTOCOL = "commit-protocol"

_BLOCKING_OPS = (isa.MB_WAIT, isa.ACQUIRE_STAGE, isa.BAR_WAIT)


@dataclass(frozen=True)
class Finding:
    """One verifier observation, anchored to a (CTA, warpgroup, pc)."""
    severity: str              # "error" | "warning"
    code: str                  # catalogue code, e.g. "deadlock"
    cta: str                   # CTA name ("" when unknown)
    wg: str                    # warpgroup role label ("" for CTA-wide)
    pc: int                    # instruction index (-1 for CTA-wide)
    op: str                    # opcode at pc ("" for CTA-wide)
    detail: str                # human-readable explanation
    witness: Tuple[str, ...] = ()   # e.g. the wait-for cycle, hop by hop

    def render(self) -> str:
        where = self.cta
        if self.wg:
            where += f"/{self.wg}"
        if self.pc >= 0:
            where += f"@{self.pc}"
        head = (f"[{self.severity.upper():7s}] {self.code:22s} {where}"
                + (f" {self.op}" if self.op else ""))
        lines = [head, f"    {self.detail}"]
        for hop in self.witness:
            lines.append(f"      | {hop}")
        return "\n".join(lines)


@dataclass
class VerifyReport:
    """Structured verdict for one lowered launch (or one CTA)."""
    kernel: str
    n_ctas: int = 0            # CTAs covered (incl. shape-deduplicated)
    n_unique: int = 0          # distinct CTA shapes actually verified
    findings: List[Finding] = field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> Set[str]:
        return {f.code for f in self.findings}

    def render(self) -> str:
        verdict = "OK" if self.ok else "ILLEGAL"
        head = (f"verify {self.kernel}: {verdict} — {self.n_ctas} CTAs "
                f"({self.n_unique} unique shapes), "
                f"{len(self.errors)} errors, {len(self.warnings)} warnings")
        return "\n".join([head] + [f.render() for f in self.findings])


class KernelVerificationError(ValueError):
    """Raised by resolve-time verification when a spec is illegal."""

    def __init__(self, report: VerifyReport):
        self.report = report
        super().__init__(f"kernel {report.kernel!r} failed static "
                         f"verification:\n{report.render()}")


# ---------------------------------------------------------------------------
# CTA metadata view
# ---------------------------------------------------------------------------

class _Meta:
    """Resolved IR metadata for one CTATrace (all fields optional on
    hand-built traces — checks that need absent metadata are skipped)."""

    def __init__(self, trace):
        self.rings: Dict[str, Tuple[int, ...]] = dict(
            getattr(trace, "rings", None) or {})
        self.tokens: Dict[str, int] = dict(
            getattr(trace, "tokens", None) or {})
        self.acq_slots: List[Dict[int, Tuple[str, int]]] = list(
            getattr(trace, "acq_slots", None) or [])
        self.ring_of_sid: Dict[int, str] = {}
        for name, sids in self.rings.items():
            for s in sids:
                # collisions between rings are reported by _check_sid_spaces;
                # keep the first owner for the protocol scans
                self.ring_of_sid.setdefault(s, name)
        self.token_sids: Set[int] = set(self.tokens.values())
        self.token_of_sid = {s: n for n, s in self.tokens.items()}
        roles = getattr(trace, "roles", None)
        self.labels = [roles[i] if roles and i < len(roles) else f"wg{i}"
                       for i in range(len(trace.wgs))]

    def stages(self, ring: str) -> int:
        return len(self.rings.get(ring, ()))

    def sid_desc(self, sid: int) -> str:
        if sid in self.ring_of_sid:
            return f"sid {sid} (ring {self.ring_of_sid[sid]!r})"
        if sid in self.token_of_sid:
            return f"sid {sid} (token {self.token_of_sid[sid]!r})"
        return f"sid {sid}"


def _operand_desc(meta: _Meta, ins: Instr) -> str:
    if ins.op in (isa.BAR_WAIT, isa.BAR_ARRIVE):
        return f"bid {ins.bid} (n>={ins.n})" if ins.op == isa.BAR_WAIT \
            else f"bid {ins.bid}"
    if ins.sid >= 0:
        return meta.sid_desc(ins.sid)
    if ins.gid >= 0:
        return f"gid {ins.gid}"
    return ""


# ---------------------------------------------------------------------------
# checker family 1: sid-space collisions
# ---------------------------------------------------------------------------

def _check_sid_spaces(trace, meta: _Meta) -> List[Finding]:
    out: List[Finding] = []
    name = getattr(trace, "name", "")
    seen: Dict[int, str] = {}
    for ring, sids in sorted(meta.rings.items()):
        for s in sids:
            if s >= isa.Q_READY_SID:
                out.append(Finding(
                    ERROR, SID_COLLISION, name, "", -1, "",
                    f"ring {ring!r} stage sid {s} lies in the "
                    f"point-to-point token range (>= Q_READY_SID="
                    f"{isa.Q_READY_SID}); ring and token signals on one "
                    f"mbarrier cannot be told apart",
                    witness=(f"ring {ring} sids: {sids}",)))
            owner = seen.get(s)
            if owner is not None and owner != ring:
                out.append(Finding(
                    ERROR, SID_COLLISION, name, "", -1, "",
                    f"rings {owner!r} and {ring!r} share stage sid {s}: "
                    f"their pipelines release into each other's slots",
                    witness=(f"{owner}: {meta.rings[owner]}",
                             f"{ring}: {sids}")))
            seen.setdefault(s, ring)
    for tok, s in sorted(meta.tokens.items()):
        if s < isa.Q_READY_SID:
            out.append(Finding(
                ERROR, SID_COLLISION, name, "", -1, "",
                f"token {tok!r} sid {s} lies in the ring stage range "
                f"(< Q_READY_SID={isa.Q_READY_SID})"))
        if s in meta.ring_of_sid:
            out.append(Finding(
                ERROR, SID_COLLISION, name, "", -1, "",
                f"token {tok!r} aliases ring {meta.ring_of_sid[s]!r} "
                f"stage sid {s}: a tile arrival would satisfy the token "
                f"wait (and vice versa)"))
    return out


# ---------------------------------------------------------------------------
# checker family 2: per-warpgroup protocol scans
# ---------------------------------------------------------------------------

def _check_wg_protocol(trace, meta: _Meta, wi: int,
                       instrs: Sequence[Instr]) -> List[Finding]:
    out: List[Finding] = []
    name = getattr(trace, "name", "")
    wg = meta.labels[wi]
    armed: Dict[int, int] = {}          # ring sid -> pc of pending acquire
    waits: Dict[int, int] = {}          # ring sid -> MB_WAIT count
    releases: Dict[int, int] = {}       # ring sid -> RELEASE count
    live_by_ring: Dict[str, List[int]] = {}   # ring -> pcs of live acquires
    max_live: Dict[str, Tuple[int, List[int]]] = {}
    self_releases: Set[str] = {
        meta.ring_of_sid[i.sid] for i in instrs
        if i.op == isa.RELEASE_STAGE and i.sid in meta.ring_of_sid}
    wg_slots = meta.acq_slots[wi] if wi < len(meta.acq_slots) else {}
    # WGMMA commit groups: gid -> [n_issued, committed]
    wgmma: Dict[int, List] = {}
    # TMA store groups: gid -> [n_stores, committed, awaited]
    stores: Dict[int, List] = {}

    for pc, ins in enumerate(instrs):
        op = ins.op
        if op == isa.ACQUIRE_STAGE:
            ring = meta.ring_of_sid.get(ins.sid)
            if ring is None:
                continue
            if ins.sid in armed:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"re-acquires {meta.sid_desc(ins.sid)} while the "
                    f"acquire at pc {armed[ins.sid]} has not been used by "
                    f"a load"))
            armed[ins.sid] = pc
            # self-releasing rings: track live (acquired, unreleased) depth
            if ring in self_releases:
                live = live_by_ring.setdefault(ring, [])
                live.append(pc)
                best = max_live.get(ring, (0, []))
                if len(live) > best[0]:
                    max_live[ring] = (len(live), list(live))
        elif op == isa.TMA_TENSOR:
            ring = meta.ring_of_sid.get(ins.sid)
            if ring is not None:
                if ins.sid in armed:
                    del armed[ins.sid]
                else:
                    out.append(Finding(
                        ERROR, UNGUARDED_LOAD, name, wg, pc, op,
                        f"TMA load into {meta.sid_desc(ins.sid)} without a "
                        f"preceding ACQUIRE_STAGE: the producer can refill "
                        f"the slot while a consumer still reads it "
                        f"(write-after-read race)"))
        elif op == isa.MB_WAIT:
            if ins.sid in meta.ring_of_sid:
                waits[ins.sid] = waits.get(ins.sid, 0) + 1
        elif op == isa.RELEASE_STAGE:
            ring = meta.ring_of_sid.get(ins.sid)
            if ring is None:
                continue
            releases[ins.sid] = releases.get(ins.sid, 0) + 1
            if releases[ins.sid] > waits.get(ins.sid, 0):
                out.append(Finding(
                    ERROR, RELEASE_WITHOUT_WAIT, name, wg, pc, op,
                    f"releases {meta.sid_desc(ins.sid)} more often than it "
                    f"has waited on it ({releases[ins.sid]} releases vs "
                    f"{waits.get(ins.sid, 0)} waits so far): the release "
                    f"un-gates the producer while another consumer may "
                    f"still be reading the stage"))
            if ring in live_by_ring and live_by_ring[ring]:
                live_by_ring[ring].pop(0)
        elif op == isa.WGMMA:
            g = wgmma.setdefault(ins.gid, [0, False])
            g[0] += 1
            if g[1]:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"WGMMA issued into gid {ins.gid} after its commit: "
                    f"the group id is being reused"))
        elif op == isa.WGMMA_COMMIT:
            g = wgmma.setdefault(ins.gid, [0, False])
            if g[0] == 0:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"commits empty WGMMA group gid {ins.gid}"))
            g[1] = True
        elif op == isa.WGMMA_WAIT:
            committed = sorted(g for g, st in wgmma.items()
                               if st[1] and g <= ins.gid)
            if ins.gid not in wgmma or not wgmma[ins.gid][1]:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"waits on WGMMA group gid {ins.gid} that was never "
                    f"committed in this warpgroup: the drain is a no-op"))
            elif ins.n > len(committed):
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"waits for <= {ins.n} outstanding groups but only "
                    f"{len(committed)} groups (ids <= {ins.gid}) were ever "
                    f"committed: wait exceeds the possible outstanding "
                    f"count and never gates anything"))
        elif op == isa.TMA_STORE:
            g = stores.setdefault(ins.gid, [0, False, False])
            g[0] += 1
            if g[1]:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"TMA store issued into gid {ins.gid} after its "
                    f"commit"))
        elif op == isa.TMA_COMMIT:
            stores.setdefault(ins.gid, [0, False, False])[1] = True
        elif op == isa.TMA_WAIT:
            covered = False
            for gid, g in stores.items():
                if gid <= ins.gid and g[1]:
                    g[2] = True
                    covered = True
            if not covered and stores:
                out.append(Finding(
                    WARNING, COMMIT_PROTOCOL, name, wg, pc, op,
                    f"TMA_WAIT on gid {ins.gid} covers no committed store "
                    f"group (store -> commit -> wait ordering broken)"))

    for sid, n_armed_pc in sorted(armed.items()):
        out.append(Finding(
            WARNING, COMMIT_PROTOCOL, name, wg, n_armed_pc,
            isa.ACQUIRE_STAGE,
            f"acquire of {meta.sid_desc(sid)} is never followed by a load"))
    for sid in sorted(set(waits) | set(releases)):
        w, r = waits.get(sid, 0), releases.get(sid, 0)
        if w > r:
            out.append(Finding(
                WARNING, WAIT_RELEASE_MISMATCH, name, wg, -1, "",
                f"waits on {meta.sid_desc(sid)} {w} times but releases it "
                f"only {r} times: the producer's ACQUIRE accounting comes "
                f"up short (a dropped release deadlocks once the ring "
                f"wraps; the final-tile case merely leaks the stage)"))
    for gid, g in sorted(stores.items()):
        if g[0] and not g[1]:
            out.append(Finding(
                WARNING, COMMIT_PROTOCOL, name, wg, -1, "",
                f"TMA store group gid {gid} is never committed: its drain "
                f"waits are no-ops and the stored bytes may still be in "
                f"flight at warpgroup retirement"))
        elif g[0] and not g[2]:
            out.append(Finding(
                WARNING, COMMIT_PROTOCOL, name, wg, -1, "",
                f"TMA store group gid {gid} is committed but never "
                f"awaited: the warpgroup can retire with the store in "
                f"flight"))
    for gid, g in sorted(wgmma.items()):
        if g[0] and not g[1]:
            out.append(Finding(
                WARNING, COMMIT_PROTOCOL, name, wg, -1, "",
                f"WGMMA group gid {gid} is never committed: no drain wait "
                f"can cover it"))

    for ring, (depth, pcs) in sorted(max_live.items()):
        stages = meta.stages(ring)
        if stages and depth > stages:
            slots = [wg_slots.get(p, (ring, -1))[1] for p in pcs]
            aliased = [
                (a, b) for i, a in enumerate(slots) for b in slots[i + 1:]
                if a >= 0 and b >= 0 and a != b
                and a % stages == b % stages]
            pair = aliased[0] if aliased else None
            out.append(Finding(
                ERROR, RING_OVERSUBSCRIPTION, name, wg, pcs[-1],
                isa.ACQUIRE_STAGE,
                f"holds {depth} live acquires on ring {ring!r} with only "
                f"{stages} stages before releasing any"
                + (f": distinct live slots {pair[0]} and {pair[1]} alias "
                   f"the same sid (slot % stages wrap)" if pair else ""),
                witness=tuple(f"acquire at pc {p} "
                              f"(slot {wg_slots.get(p, ('?', '?'))[1]})"
                              for p in pcs)))
    return out


# ---------------------------------------------------------------------------
# checker family 3: CTA-wide count checks
# ---------------------------------------------------------------------------

def _check_counts(trace, meta: _Meta) -> List[Finding]:
    out: List[Finding] = []
    name = getattr(trace, "name", "")
    signals: Dict[int, int] = {}
    arrivals: Dict[int, int] = {}
    for instrs in trace.wgs:
        for ins in instrs:
            if ins.op == isa.TMA_TENSOR:
                signals[ins.sid] = signals.get(ins.sid, 0) + 1
            elif ins.op == isa.BAR_ARRIVE:
                arrivals[ins.bid] = arrivals.get(ins.bid, 0) + 1

    for wi, instrs in enumerate(trace.wgs):
        waits: Dict[int, int] = {}
        for pc, ins in enumerate(instrs):
            if ins.op == isa.MB_WAIT:
                waits[ins.sid] = waits.get(ins.sid, 0) + 1
                if waits[ins.sid] == signals.get(ins.sid, 0) + 1:
                    out.append(Finding(
                        ERROR, UNSATISFIABLE_WAIT, name, meta.labels[wi],
                        pc, ins.op,
                        f"wait #{waits[ins.sid]} on "
                        f"{meta.sid_desc(ins.sid)} has no reaching "
                        f"signaler: the whole CTA only ever signals it "
                        f"{signals.get(ins.sid, 0)} times"))
            elif ins.op == isa.BAR_WAIT:
                if ins.n > arrivals.get(ins.bid, 0):
                    out.append(Finding(
                        ERROR, BARRIER_UNDERFLOW, name, meta.labels[wi],
                        pc, ins.op,
                        f"waits for >= {ins.n} arrivals on named barrier "
                        f"bid {ins.bid} but the CTA only ever arrives "
                        f"{arrivals.get(ins.bid, 0)} times"))

    # ring consumer cardinality: the ACQUIRE protocol divides the release
    # count by n_consumers, so the set of releasing warpgroups must match
    for ring, sids in sorted(meta.rings.items()):
        sid_set = set(sids)
        releasers = [meta.labels[wi] for wi, instrs in enumerate(trace.wgs)
                     if any(i.op == isa.RELEASE_STAGE and i.sid in sid_set
                            for i in instrs)]
        used = any(i.op == isa.MB_WAIT and i.sid in sid_set
                   for instrs in trace.wgs for i in instrs)
        n_cons = trace.n_consumers
        if len(releasers) > n_cons:
            out.append(Finding(
                ERROR, CONSUMER_MISMATCH, name, "", -1, "",
                f"ring {ring!r} is released by {len(releasers)} warpgroups "
                f"({', '.join(releasers)}) but the CTA declares "
                f"n_consumers={n_cons}: the producer's ACQUIRE un-gates "
                f"after only {n_cons} releases, refilling a stage other "
                f"consumers still read"))
        elif releasers and len(releasers) < n_cons and used:
            out.append(Finding(
                WARNING, CONSUMER_MISMATCH, name, "", -1, "",
                f"ring {ring!r} is released by only {len(releasers)} of "
                f"the declared n_consumers={n_cons} warpgroups: ACQUIRE "
                f"accounting can never reach its threshold once the ring "
                f"wraps"))
    return out


# ---------------------------------------------------------------------------
# checker family 4: abstract concurrent execution (deadlock freedom)
# ---------------------------------------------------------------------------

class _AbstractCTA:
    """Maximal-progress execution of one CTA's warpgroups with instant
    async completion.  All engine wait conditions are monotone counters, so
    the quiescent point is unique — this completes iff the engine does."""

    def __init__(self, trace, meta: _Meta):
        self.trace = trace
        self.meta = meta
        self.n_wgs = len(trace.wgs)
        self.pcs = [0] * self.n_wgs
        self.mbar: Dict[int, int] = {}
        self.releases: Dict[int, int] = {}
        self.arrivals: Dict[int, int] = {}
        self.mb_expected = [dict() for _ in range(self.n_wgs)]
        self.acq_count = [dict() for _ in range(self.n_wgs)]
        self.n_consumers = trace.n_consumers

    def _satisfiable(self, wi: int, ins: Instr) -> bool:
        op = ins.op
        if op == isa.MB_WAIT:
            need = self.mb_expected[wi].get(ins.sid, 0) + 1
            return self.mbar.get(ins.sid, 0) >= need
        if op == isa.ACQUIRE_STAGE:
            use = self.acq_count[wi].get(ins.sid, 0)
            if use == 0:
                return True
            return self.releases.get(ins.sid, 0) >= use * self.n_consumers
        if op == isa.BAR_WAIT:
            return self.arrivals.get(ins.bid, 0) >= ins.n
        return True          # WGMMA/TMA groups: async completion is instant

    def _advance(self, wi: int) -> bool:
        instrs = self.trace.wgs[wi]
        progressed = False
        while self.pcs[wi] < len(instrs):
            ins = instrs[self.pcs[wi]]
            if ins.op in _BLOCKING_OPS and not self._satisfiable(wi, ins):
                return progressed
            op = ins.op
            if op == isa.MB_WAIT:
                d = self.mb_expected[wi]
                d[ins.sid] = d.get(ins.sid, 0) + 1
            elif op == isa.ACQUIRE_STAGE:
                d = self.acq_count[wi]
                d[ins.sid] = d.get(ins.sid, 0) + 1
            elif op == isa.TMA_TENSOR:
                self.mbar[ins.sid] = self.mbar.get(ins.sid, 0) + 1
            elif op == isa.RELEASE_STAGE:
                self.releases[ins.sid] = self.releases.get(ins.sid, 0) + 1
            elif op == isa.BAR_ARRIVE:
                self.arrivals[ins.bid] = self.arrivals.get(ins.bid, 0) + 1
            self.pcs[wi] += 1
            progressed = True
        return progressed

    def run(self) -> List[int]:
        """Execute to quiescence; return the indices of blocked WGs."""
        progressed = True
        while progressed:
            progressed = False
            for wi in range(self.n_wgs):
                if self._advance(wi):
                    progressed = True
        return [wi for wi in range(self.n_wgs)
                if self.pcs[wi] < len(self.trace.wgs[wi])]

    # -- post-quiescence analysis --------------------------------------
    def _providers(self, wi: int) -> List[int]:
        """Blocked WGs whose remaining stream contains an op that would
        advance ``wi``'s unsatisfied condition (done WGs never qualify —
        their remaining stream is empty)."""
        ins = self.trace.wgs[wi][self.pcs[wi]]
        if ins.op == isa.MB_WAIT:
            match = (isa.TMA_TENSOR, "sid", ins.sid)
        elif ins.op == isa.ACQUIRE_STAGE:
            match = (isa.RELEASE_STAGE, "sid", ins.sid)
        else:
            match = (isa.BAR_ARRIVE, "bid", ins.bid)
        op, attr, val = match
        out = []
        for wj in range(self.n_wgs):
            start = self.pcs[wj] + (1 if wj == wi else 0)
            if any(i.op == op and getattr(i, attr) == val
                   for i in self.trace.wgs[wj][start:]):
                out.append(wj)
        return out

    def _live_holds(self, wi: int, ring: str) -> int:
        """Acquires by ``wi`` on ``ring`` not yet retired by releases."""
        held = 0
        for sid in self.meta.rings.get(ring, ()):
            acq = self.acq_count[wi].get(sid, 0)
            retired = min(acq, self.releases.get(sid, 0) // self.n_consumers)
            held += acq - retired
        return held

    def _blocked_desc(self, wi: int) -> str:
        pc = self.pcs[wi]
        ins = self.trace.wgs[wi][pc]
        return (f"{self.meta.labels[wi]} blocked at pc {pc} on {ins.op} "
                f"{_operand_desc(self.meta, ins)}")

    def diagnose(self, blocked: List[int]) -> List[Finding]:
        meta = self.meta
        name = getattr(self.trace, "name", "")
        out: List[Finding] = []
        edges: Dict[int, List[int]] = {}
        for wi in blocked:
            pc = self.pcs[wi]
            ins = self.trace.wgs[wi][pc]
            providers = self._providers(wi)
            if not providers:
                if ins.op == isa.BAR_WAIT:
                    code, extra = BARRIER_UNDERFLOW, \
                        "no remaining BAR_ARRIVE can raise the count"
                elif ins.op == isa.ACQUIRE_STAGE:
                    ring = meta.ring_of_sid.get(ins.sid)
                    stages = meta.stages(ring) if ring else 0
                    if ring and self._live_holds(wi, ring) >= stages > 0:
                        code = RING_OVERSUBSCRIPTION
                        extra = (f"all {stages} stages of ring {ring!r} are "
                                 f"held and nothing will release them")
                    else:
                        code, extra = UNSATISFIABLE_WAIT, \
                            "no remaining RELEASE_STAGE feeds this acquire"
                else:
                    code, extra = UNSATISFIABLE_WAIT, \
                        "no remaining signaler for this mbarrier"
                out.append(Finding(
                    ERROR, code, name, meta.labels[wi], pc, ins.op,
                    f"{self._blocked_desc(wi)}: {extra}",
                    witness=tuple(self._blocked_desc(w) for w in blocked)))
            else:
                edges[wi] = providers
        if out or not edges:
            return out
        cycle = _shortest_cycle(edges)
        if cycle is None:        # defensive: quiescence + providers => cycle
            cycle = sorted(edges)
        hops = []
        for i, wi in enumerate(cycle):
            nxt = cycle[(i + 1) % len(cycle)]
            hops.append(f"{self._blocked_desc(wi)} "
                        f"-> provided by {meta.labels[nxt]}")
        head = self.trace.wgs[cycle[0]][self.pcs[cycle[0]]]
        # classify: a circular wait whose head is a full-ring acquire is the
        # over-subscription shape (producer ran ahead of every release)
        ring = meta.ring_of_sid.get(head.sid) \
            if head.op == isa.ACQUIRE_STAGE else None
        code = DEADLOCK
        if ring and self._live_holds(cycle[0], ring) >= meta.stages(ring) > 0:
            code = RING_OVERSUBSCRIPTION
        out.append(Finding(
            ERROR, code, name, meta.labels[cycle[0]], self.pcs[cycle[0]],
            head.op,
            f"circular wait across {len(cycle)} warpgroup(s); "
            f"{len(blocked)} of {self.n_wgs} warpgroups blocked at "
            f"quiescence",
            witness=tuple(hops)))
        return out


def _shortest_cycle(edges: Dict[int, List[int]]) -> Optional[List[int]]:
    """Minimal witness: BFS from each node over the wait-for edges; the
    shortest path back to its start is the smallest cycle through it."""
    best: Optional[List[int]] = None
    for start in sorted(edges):
        prev: Dict[int, Optional[int]] = {start: None}
        q = deque([start])
        found: Optional[List[int]] = None
        while q and found is None:
            u = q.popleft()
            for v in edges.get(u, ()):
                if v == start:
                    path, node = [], u
                    while node is not None:
                        path.append(node)
                        node = prev[node]
                    found = list(reversed(path))     # [start, ..., u]
                    break
                if v not in prev:
                    prev[v] = u
                    q.append(v)
        if found is not None and (best is None or len(found) < len(best)):
            best = found
    return best


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def verify_cta(trace) -> List[Finding]:
    """All findings for one lowered :class:`CTATrace`."""
    meta = _Meta(trace)
    findings = _check_sid_spaces(trace, meta)
    for wi, instrs in enumerate(trace.wgs):
        findings += _check_wg_protocol(trace, meta, wi, instrs)
    findings += _check_counts(trace, meta)
    ax = _AbstractCTA(trace, meta)
    blocked = ax.run()
    if blocked:
        findings += ax.diagnose(blocked)
    return findings


def _signature(trace):
    return (tuple(tuple(wg) for wg in trace.wgs),
            trace.n_consumers,
            tuple(sorted((getattr(trace, "rings", None) or {}).items())),
            tuple(sorted((getattr(trace, "tokens", None) or {}).items())))


def verify_ctas(ctas: Sequence, kernel: str = "?") -> VerifyReport:
    """Verify a lowered launch, deduplicating structurally identical CTAs
    (a launch is thousands of copies of a handful of shapes)."""
    rep = VerifyReport(kernel=kernel, n_ctas=len(ctas))
    seen = set()
    for trace in ctas:
        sig = _signature(trace)
        if sig in seen:
            continue
        seen.add(sig)
        rep.findings.extend(verify_cta(trace))
    rep.n_unique = len(seen)
    return rep


def verify_spec(spec, cfg=None, w=None, tiling=None,
                max_ctas: Optional[int] = 64) -> VerifyReport:
    """Lower a spec's probe launch (or the given workload) and verify it.
    This is what ``registry.get`` runs once per spec at resolve time."""
    if cfg is None:
        from repro.core.machine import H800
        cfg = H800
    if w is None:
        w = spec.probe_workload()
    ctas, _ = spec.build(cfg, w, tiling=tiling, max_ctas=max_ctas)
    return verify_ctas(ctas, kernel=getattr(spec, "name", "?"))
