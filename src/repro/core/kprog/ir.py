"""Kernel-program IR: declarative warp-specialization layer.

Warp-specialized pipelines are naturally described as role-annotated async
dataflow (Tawa, arXiv:2510.14719) rather than unrolled instruction lists.
A :class:`KernelSpec` declares

  * warpgroup **roles** (``producer``, ``consumer`` x2, ...) — the CTA's
    logical threads, named so downstream analysis can aggregate by role
    instead of hardcoded WG indices;
  * **ring buffers** (named, staged) — the K/V smem pipelines; the builder
    owns the mapping from (ring, slot) to mbarrier/stage sids;
  * per-iteration **async ops with named tokens** — loads signal tokens,
    consumers wait on them, named barriers pass scheduling tokens between
    roles (ping-pong).

``KernelSpec.build()`` lowers a spec to the existing ``isa.Instr`` lists /
:class:`~repro.core.engine.CTATrace` the cycle engine consumes — the IR is
a front end, the engine and its waiter-indexed scheduler are unchanged.
Lowering is deterministic and bit-stable: the registered FA3 ping-pong spec
reproduces the pre-IR hardcoded generator instruction-for-instruction
(``tests/test_kprog.py``), so golden cycle anchors do not move.

Number assignment rules (all bookkeeping the old generators did by hand):

  * ring sids — slot-major interleave across the declared rings when all
    rings share a stage count (K/V ping-pong layout: K->0,2  V->1,3),
    contiguous per-ring blocks otherwise;
  * token sids — allocated upward from ``isa.Q_READY_SID`` in first-use
    order;
  * named-barrier bids — first-use order from 0;
  * WGMMA commit groups — a per-warpgroup counter, one gid per ``gemm()``;
  * epilogue TMA store groups — ``isa.EPILOGUE_GID``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core import isa
from repro.core.engine import CTATrace
from repro.core.isa import Instr, TensorMap
from repro.core.machine import GPUMachine


@dataclass(frozen=True)
class Role:
    """One warpgroup role; ``count`` instances share the role body."""
    name: str
    count: int = 1

    def labels(self) -> List[str]:
        if self.count == 1:
            return [self.name]
        return [f"{self.name}{i}" for i in range(self.count)]


@dataclass(frozen=True)
class Ring:
    """A named smem ring buffer streamed through ACQUIRE/RELEASE stages."""
    name: str
    stages: int


class WGProgram:
    """Instruction emitter for one warpgroup, written in role/token
    vocabulary; owns the per-WG WGMMA commit-group counter."""

    def __init__(self, builder: "CTABuilder", label: str):
        self.builder = builder
        self.label = label
        self.instrs: List[Instr] = []
        self._gid = 0
        # instr index -> (ring, raw slot) for every acquire; rides along on
        # CTATrace.acq_slots so the verifier can reconstruct pre-wrap slot
        # numbers (sid folds slot % stages — see CTABuilder.sid)
        self.acq_slots: Dict[int, Tuple[str, int]] = {}

    # -- producer side -------------------------------------------------
    def acquire(self, ring: str, slot: int) -> None:
        """pipeline.producer_acquire on the ring slot (blocks while full)."""
        self.acq_slots[len(self.instrs)] = (ring, slot)
        self.instrs.append(Instr(isa.ACQUIRE_STAGE,
                                 sid=self.builder.sid(ring, slot)))

    def load(self, map_id: int, origin: Tuple[int, ...], *,
             ring: Optional[str] = None, slot: int = 0,
             token: Optional[str] = None, tag: str = "",
             bulk: bool = False) -> None:
        """Async TMA tile load signalling either a ring slot or a named
        point-to-point token."""
        if (ring is None) == (token is None):
            raise ValueError("load() needs exactly one of ring= or token=")
        sid = (self.builder.token(token) if token is not None
               else self.builder.sid(ring, slot))
        self.instrs.append(Instr(isa.TMA_TENSOR, map_id=map_id, sid=sid,
                                 origin=origin, tag=tag, bulk=bulk))

    # -- consumer side -------------------------------------------------
    def wait_tile(self, ring: str, slot: int) -> None:
        self.instrs.append(Instr(isa.MB_WAIT,
                                 sid=self.builder.sid(ring, slot)))

    def wait_token(self, token: str) -> None:
        self.instrs.append(Instr(isa.MB_WAIT, sid=self.builder.token(token)))

    def release(self, ring: str, slot: int) -> None:
        self.instrs.append(Instr(isa.RELEASE_STAGE,
                                 sid=self.builder.sid(ring, slot)))

    # -- named-barrier scheduling tokens -------------------------------
    def arrive(self, bar: str) -> None:
        self.instrs.append(Instr(isa.BAR_ARRIVE, bid=self.builder.bar(bar)))

    def await_arrivals(self, bar: str, n: int) -> None:
        """Block until the named barrier has >= ``n`` total arrivals."""
        self.instrs.append(Instr(isa.BAR_WAIT, bid=self.builder.bar(bar),
                                 n=n))

    # -- compute -------------------------------------------------------
    def gemm(self, *, m: int, n: int, steps: int, tag: str = "",
             wait: int = 0) -> int:
        """One logical GEMM: ``steps`` k16 WGMMAs sharing a fresh commit
        group, committed, then drained down to ``wait`` outstanding groups
        (``wait=1`` leaves this group in flight — FA3's WAIT_WG_1)."""
        gid = self._gid
        self._gid += 1
        for _ in range(steps):
            self.instrs.append(Instr(isa.WGMMA, gid=gid, m=m, n=n, k=16,
                                     tag=tag))
        self.instrs.append(Instr(isa.WGMMA_COMMIT, gid=gid))
        self.instrs.append(Instr(isa.WGMMA_WAIT, gid=gid, n=wait))
        return gid

    def bubbles(self, cycles: int) -> None:
        if cycles > 0:
            self.instrs.append(Instr(isa.BUBBLES, cycles=cycles))

    # -- epilogue ------------------------------------------------------
    def store(self, map_id: int, origin: Tuple[int, ...], *, tag: str = "",
              gid: int = isa.EPILOGUE_GID) -> None:
        """Async TMA store + commit + full drain (epilogue group)."""
        self.instrs.append(Instr(isa.TMA_STORE, map_id=map_id, gid=gid,
                                 origin=origin, tag=tag))
        self.instrs.append(Instr(isa.TMA_COMMIT, gid=gid))
        self.instrs.append(Instr(isa.TMA_WAIT, gid=gid, n=0))


class CTABuilder:
    """Allocates sids/bids/tokens for one CTA and collects its role
    programs into a :class:`CTATrace`."""

    def __init__(self, rings: Iterable[Ring] = (), n_consumers: int = 1,
                 name: str = ""):
        self.rings = list(rings)
        self.n_consumers = n_consumers
        self.name = name
        self._ring_index = {r.name: i for i, r in enumerate(self.rings)}
        stage_counts = {r.stages for r in self.rings}
        self._interleaved = len(stage_counts) <= 1
        if not self._interleaved:
            base, self._ring_base = 0, {}
            for r in self.rings:
                self._ring_base[r.name] = base
                base += r.stages
        self._tokens: Dict[str, int] = {}
        self._bars: Dict[str, int] = {}
        self._wgs: List[Tuple[str, WGProgram]] = []

    # -- number assignment ---------------------------------------------
    def sid(self, ring: str, slot: int) -> int:
        """Map a (ring, slot) to its mbarrier/stage sid.

        **Wrap contract**: ``slot`` is an *iteration* index, not a physical
        stage — it wraps modulo the ring's declared ``stages`` (slot ``j``
        and ``j + stages`` share a sid on purpose; the ACQUIRE/RELEASE
        counting protocol serializes the reuse).  The wrap is silent by
        design: callers write natural loop indices and the builder owns the
        fold.  What the wrap must *never* do is alias two slots that are
        live at the same time — that is a spec bug (e.g. a prefetch depth
        exceeding ``stages``), and the static verifier
        (``repro.core.kprog.verify``) flags it as ``ring-oversubscription``
        with the pre-wrap slot numbers as witness (recorded per acquire in
        ``CTATrace.acq_slots``)."""
        r = self.rings[self._ring_index[ring]]
        if self._interleaved:
            return (slot % r.stages) * len(self.rings) + self._ring_index[ring]
        return self._ring_base[ring] + slot % r.stages

    def token(self, name: str) -> int:
        if name not in self._tokens:
            self._tokens[name] = isa.Q_READY_SID + len(self._tokens)
        return self._tokens[name]

    def bar(self, name: str) -> int:
        if name not in self._bars:
            self._bars[name] = len(self._bars)
        return self._bars[name]

    # -- role programs ---------------------------------------------------
    def wg(self, label: str) -> WGProgram:
        prog = WGProgram(self, label)
        self._wgs.append((label, prog))
        return prog

    def finish(self) -> CTATrace:
        # ring -> stage-sid metadata rides along so observability can map
        # mbarrier/release state back to declared ring buffers; the engine
        # itself never reads it.  Token sids and per-acquire raw slots ride
        # along for the static verifier (sid-space collisions, aliasing
        # witnesses).
        rings = {r.name: tuple(self.sid(r.name, s) for s in range(r.stages))
                 for r in self.rings}
        return CTATrace(wgs=[p.instrs for _, p in self._wgs],
                        n_consumers=self.n_consumers, name=self.name,
                        roles=[lbl for lbl, _ in self._wgs],
                        rings=rings or None,
                        tokens=dict(self._tokens) or None,
                        acq_slots=[dict(p.acq_slots) for _, p in self._wgs])


class KernelSpec:
    """Base class for registered kernel programs.

    Subclasses declare ``name``/``roles``/``scheduling`` and implement the
    geometry (``grid``/``tmaps``/``total_ctas``) plus ``cta()`` — the role
    programs, written against :class:`CTABuilder`.  The analytical traffic
    hooks let SimFA-python (Eq. 2/3/6) specialize per scenario; the defaults
    raise so a new kernel cannot silently inherit FA3 arithmetic.
    """

    name: str = "?"
    roles: Tuple[Role, ...] = ()
    scheduling: str = "?"          # "ping-pong" | "cooperative" | ...

    # -- geometry --------------------------------------------------------
    def default_tiling(self):
        raise NotImplementedError

    def probe_workload(self):
        """A minimal representative workload for resolve-time verification
        (``registry.get`` statically verifies each spec's lowered probe
        launch once).  The default prefill shape exercises ring wrap
        (several KV tiles per ring stage) and grouped heads; decode-shaped
        kernels override (``w.L`` must be 1 there)."""
        from repro.configs.llama3 import AttnWorkload
        return AttnWorkload(name=f"{self.name}-probe", B=1, L=128, S=704,
                            H_kv=1, G=2, D=64)

    def grid(self, w, tiling) -> Iterable[dict]:
        """CTA coordinates in launch (rasterization) order."""
        raise NotImplementedError

    def tmaps(self, w, tiling) -> Dict[int, TensorMap]:
        raise NotImplementedError

    def total_ctas(self, w, tiling=None) -> int:
        """Analytic CTA count of the full launch (no trace materialized)."""
        raise NotImplementedError

    def cta(self, cfg: GPUMachine, w, tiling, **coords) -> CTATrace:
        raise NotImplementedError

    # -- lowering --------------------------------------------------------
    def build(self, cfg: GPUMachine, w, tiling=None,
              max_ctas: Optional[int] = None
              ) -> Tuple[List[CTATrace], Dict[int, TensorMap]]:
        """Lower the first ``max_ctas`` CTAs (all when None) to engine
        traces.  ``max_ctas=0`` means zero CTAs, not unlimited."""
        tiling = tiling if tiling is not None else self.default_tiling()
        tmaps = self.tmaps(w, tiling)
        ctas: List[CTATrace] = []
        for coords in self.grid(w, tiling):
            if max_ctas is not None and len(ctas) >= max_ctas:
                break
            ctas.append(self.cta(cfg, w, tiling, **coords))
        return ctas, tmaps

    # -- analytical traffic hooks (SimFA-python Eq. 2/3/6 per kernel) ----
    def flops(self, w) -> float:
        from repro.core import analytical
        return analytical.total_flops(w)

    def ramp_bubble_cycles(self, cfg: GPUMachine, w, t_m: int,
                           t_n: int) -> int:
        """One steady-state softmax-bubble block for the analytical ramp
        (fill/drain) term.  The default charges the standard (t_m x t_n)
        consumer tile; kernels with differently shaped compute blocks
        (e.g. decode's G-row tiles) override."""
        from repro.core.kprog.costs import softmax_bubble_cycles
        return softmax_bubble_cycles(cfg, t_m, t_n, w.D)

    def l2_traffic(self, w, t_m: int = 64, tiling=None) -> float:
        raise NotImplementedError(f"{self.name}: no L2 traffic hook")

    def dram_ideal(self, w) -> float:
        raise NotImplementedError(f"{self.name}: no ideal-DRAM hook")

    def dram_real(self, w, t_m: int, n_sm: int, o_limit: int,
                  tiling=None) -> float:
        raise NotImplementedError(f"{self.name}: no real-DRAM hook")
