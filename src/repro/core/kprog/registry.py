"""Kernel registry: name -> KernelSpec.

Built-in specs (fa3, fa3_cooperative, fa2, splitkv_decode) self-register on
first lookup; external code can register additional specs with
:func:`register` before driving them through ``simulate_fa3(kernel=...)``.

Resolution doubles as the legality gate: :func:`get` statically verifies
each spec once (lowering its probe workload through
:mod:`repro.core.kprog.verify`) and raises
:class:`~repro.core.kprog.verify.KernelVerificationError` on deadlocks or
protocol violations, so an illegal spec fails in microseconds at resolve
time instead of timing out a simulation.  Opt out per call
(``get(k, verify=False)``) or process-wide (``REPRO_KPROG_VERIFY=0``).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Union

from repro.core.kprog.ir import KernelSpec

_REGISTRY: Dict[str, KernelSpec] = {}
_BUILTINS_LOADED = False
_VERIFY_ENV = "REPRO_KPROG_VERIFY"


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    # imports self-register; deferred so repro.core.analytical (imported by
    # the spec modules) never cycles at module-import time.  The flag flips
    # only on success so a failed import surfaces again on the next lookup
    # instead of leaving a silently empty registry.
    from repro.core.kprog import decode, fa2, fa3  # noqa: F401
    _BUILTINS_LOADED = True


def _verify_once(spec: KernelSpec) -> KernelSpec:
    """Run resolve-time static verification, cached per spec instance.
    Errors raise; warnings are tolerated (the report is kept on the spec
    as ``_kprog_verify_report`` for callers that want to inspect it)."""
    if getattr(spec, "_kprog_verified", False):
        return spec
    from repro.core.kprog.verify import KernelVerificationError, verify_spec
    report = verify_spec(spec)
    spec._kprog_verify_report = report
    if not report.ok:
        raise KernelVerificationError(report)
    spec._kprog_verified = True
    return spec


def get(kernel: Union[str, KernelSpec], *,
        verify: Optional[bool] = None) -> KernelSpec:
    """Resolve a kernel name (or pass a spec through), statically verifying
    the spec once at first resolution.

    ``verify=None`` follows the ``REPRO_KPROG_VERIFY`` env switch (default
    on); ``verify=False`` skips the check for this call; ``verify=True``
    forces it regardless of the environment.
    """
    if verify is None:
        verify = os.environ.get(_VERIFY_ENV, "1") not in ("0", "off", "no")
    if isinstance(kernel, KernelSpec):
        return _verify_once(kernel) if verify else kernel
    _ensure_builtins()
    try:
        spec = _REGISTRY[kernel]
    except KeyError:
        raise KeyError(f"unknown kernel {kernel!r}; "
                       f"available: {sorted(_REGISTRY)}") from None
    return _verify_once(spec) if verify else spec


def available() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
