"""Kernel registry: name -> KernelSpec.

Built-in specs (fa3, fa3_cooperative, fa2, splitkv_decode) self-register on
first lookup; external code can register additional specs with
:func:`register` before driving them through ``simulate_fa3(kernel=...)``.
"""
from __future__ import annotations

from typing import Dict, List, Union

from repro.core.kprog.ir import KernelSpec

_REGISTRY: Dict[str, KernelSpec] = {}
_BUILTINS_LOADED = False


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    # imports self-register; deferred so repro.core.analytical (imported by
    # the spec modules) never cycles at module-import time.  The flag flips
    # only on success so a failed import surfaces again on the next lookup
    # instead of leaving a silently empty registry.
    from repro.core.kprog import decode, fa2, fa3  # noqa: F401
    _BUILTINS_LOADED = True


def get(kernel: Union[str, KernelSpec]) -> KernelSpec:
    """Resolve a kernel name (or pass a spec through)."""
    if isinstance(kernel, KernelSpec):
        return kernel
    _ensure_builtins()
    try:
        return _REGISTRY[kernel]
    except KeyError:
        raise KeyError(f"unknown kernel {kernel!r}; "
                       f"available: {sorted(_REGISTRY)}") from None


def available() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
