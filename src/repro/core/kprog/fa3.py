"""FlashAttention-3 kernel specs (paper §5.1-§5.2, Table 4).

Two scheduling variants of the 1-producer / 2-consumer warp-specialized
kernel (Hopper dissection taxonomy, arXiv:2402.13499):

  * ``fa3`` — **ping-pong**: the consumers pass MMA/softmax tokens through
    two named barriers so one warpgroup's softmax hides behind the other's
    WGMMAs.  This spec lowers instruction-for-instruction to the pre-IR
    hardcoded generator (golden anchor: the reference full-fidelity launch
    stays at 73614 cycles).
  * ``fa3_cooperative`` — same per-warpgroup work, but the consumers run
    in lockstep with no token pass and drain each QK group before its
    softmax; both bubbles land concurrently, so the tensor core idles
    through them (the bubble-exposure ablation).

Having no H800 to instrument, the "runtime log" phase is replaced by a
schedule-exact generator that walks the same loop structure as the FA3
kernel — the translation rules from events to instructions are the paper's.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.core import analytical
from repro.core.engine import CTATrace
from repro.core.isa import TensorMap
from repro.core.kprog import registry
from repro.core.kprog.costs import (DEFAULT_T_M, DEFAULT_T_N,
                                    softmax_bubble_cycles)
from repro.core.kprog.ir import CTABuilder, KernelSpec, Ring, Role
from repro.core.machine import GPUMachine

# tensor-map ids
TM_Q, TM_K, TM_V, TM_O = 0, 1, 2, 3


@dataclass(frozen=True)
class FA3Tiling:
    t_m: int = DEFAULT_T_M     # query rows per CTA (per paper §5.2)
    t_n: int = DEFAULT_T_N     # kv tile rows
    stages: int = 2            # ring-buffer stages for K and V each
    precision: int = 2         # fp16


def make_tmaps(B: int, L: int, S: int, H_q: int, H_kv: int, D: int,
               tiling: FA3Tiling, base: int = 0) -> Dict[int, TensorMap]:
    """Layouts follow the FA3 kernel's (B, S, H, D) tensors: consecutive
    sequence rows of one head are H*D*P bytes apart — the 2048-byte strides
    that concentrate requests on L2 slices under a naive low-bit hash
    (paper §5.4). A head's tile is addressed via an inner-dim origin offset
    of h*D elements."""
    P = tiling.precision
    sz_q = B * L * H_q * D * P
    sz_kv = B * S * H_kv * D * P
    return {
        TM_Q: TensorMap(TM_Q, base, (B, L, H_q * D),
                        (L * H_q * D * P, H_q * D * P, P),
                        (1, tiling.t_m, D), P),
        TM_K: TensorMap(TM_K, base + sz_q, (B, S, H_kv * D),
                        (S * H_kv * D * P, H_kv * D * P, P),
                        (1, tiling.t_n, D), P),
        TM_V: TensorMap(TM_V, base + sz_q + sz_kv, (B, S, H_kv * D),
                        (S * H_kv * D * P, H_kv * D * P, P),
                        (1, tiling.t_n, D), P),
        TM_O: TensorMap(TM_O, base + sz_q + 2 * sz_kv, (B, L, H_q * D),
                        (L * H_q * D * P, H_q * D * P, P),
                        (1, tiling.t_m, D), P),
    }


def _n_kv_tiles(w, tiling: FA3Tiling, q_block: int,
                q_base_row: int = 0) -> int:
    n_tiles = math.ceil(w.S / tiling.t_n)
    if w.causal:
        last_row = q_base_row + q_block * tiling.t_m + tiling.t_m - 1
        n_tiles = min(n_tiles, math.ceil((last_row + 1) / tiling.t_n))
    return n_tiles


class FA3PingPong(KernelSpec):
    """FA3 with ping-pong consumer scheduling (the paper's kernel)."""

    name = "fa3"
    roles = (Role("producer"), Role("consumer", 2))
    scheduling = "ping-pong"

    def default_tiling(self) -> FA3Tiling:
        return FA3Tiling()

    # -- geometry --------------------------------------------------------
    def grid(self, w, tiling: FA3Tiling):
        """Head-major rasterization: one wave works on as few distinct KV
        heads as possible — the reuse structure behind Eq. (5)/(6)."""
        n_q = math.ceil(w.L / tiling.t_m)
        for b in range(w.B):
            for hkv in range(w.H_kv):
                for g in range(w.G):
                    hq = hkv * w.G + g
                    for qb in range(n_q):
                        yield dict(b=b, h_q=hq, h_kv=hkv, q_block=qb)

    def tmaps(self, w, tiling: FA3Tiling) -> Dict[int, TensorMap]:
        return make_tmaps(w.B, w.L, w.S, w.H_kv * w.G, w.H_kv, w.D, tiling)

    def total_ctas(self, w, tiling: FA3Tiling = None) -> int:
        tiling = tiling if tiling is not None else self.default_tiling()
        return w.B * w.H_kv * w.G * math.ceil(w.L / tiling.t_m)

    # -- role programs ---------------------------------------------------
    def cta(self, cfg: GPUMachine, w, tiling: FA3Tiling, *, b: int,
            h_q: int, h_kv: int, q_block: int,
            q_base_row: int = 0) -> CTATrace:
        t_m, t_n, D = tiling.t_m, tiling.t_n, w.D
        n_tiles = _n_kv_tiles(w, tiling, q_block, q_base_row)
        bubbles = softmax_bubble_cycles(cfg, t_m, t_n, D)
        n_qk = D // 16                      # 8 WGMMAs per QK GEMM (§5.2)
        n_pv = math.ceil(t_n / 16)          # 11 WGMMAs per PV GEMM

        cb = CTABuilder(rings=(Ring("K", tiling.stages),
                               Ring("V", tiling.stages)),
                        n_consumers=2, name=f"b{b}h{h_q}q{q_block}")

        # producer: Q first, then stream K/V tiles through the ring buffer
        p = cb.wg("producer")
        p.load(TM_Q, (b, q_block * t_m, h_q * D), token="q_ready", tag="Q")
        for j in range(n_tiles):
            p.acquire("K", j)
            p.load(TM_K, (b, j * t_n, h_kv * D), ring="K", slot=j,
                   tag=f"K{j}")
            p.acquire("V", j)
            p.load(TM_V, (b, j * t_n, h_kv * D), ring="V", slot=j,
                   tag=f"V{j}")

        # consumers: ping-pong via two named barriers ("mma" token release,
        # "softmax" token release); await_arrivals uses absolute thresholds
        for c in (0, 1):
            t = cb.wg(f"consumer{c}")
            t.wait_token("q_ready")
            for j in range(n_tiles):
                t.wait_tile("K", j)
                if c == 0:
                    # consumer0 announces it's entering MMA; consumer1 waits
                    t.arrive("mma")
                else:
                    t.await_arrivals("mma", j + 1)
                t.gemm(m=t_m, n=t_n, steps=n_qk, tag=f"QK{j}", wait=1)
                t.release("K", j)                 # K done (§5.2)
                if c == 0:
                    t.await_arrivals("softmax", j + 1)
                else:
                    t.arrive("softmax")
                t.bubbles(bubbles)                # softmax block
                t.wait_tile("V", j)
                t.gemm(m=t_m, n=D, steps=n_pv, tag=f"PV{j}", wait=0)
                t.release("V", j)                 # V done
            t.store(TM_O, (b, q_block * t_m, h_q * D), tag="O")

        return cb.finish()

    # -- analytical hooks: the paper's FA3 equations ---------------------
    def l2_traffic(self, w, t_m: int = 64, tiling=None) -> float:
        return analytical.l2_traffic(w, t_m)

    def dram_ideal(self, w) -> float:
        return analytical.dram_ideal(w)

    def dram_real(self, w, t_m: int, n_sm: int, o_limit: int,
                  tiling=None) -> float:
        return analytical.dram_real(w, t_m, n_sm, o_limit)


class FA3Cooperative(FA3PingPong):
    """FA3 with cooperative consumer scheduling: the two consumer
    warpgroups share each tile in lockstep — same producer, same ring
    buffer, same per-warpgroup instruction work as ping-pong (the seed's
    convention: each consumer warpgroup runs the full tile loop) — but
    **no named-barrier token pass**, and the QK group drains fully
    (``wait=0``) before the softmax: without an opposite-phase warpgroup
    to pipeline behind, the softmax consumes the scores its own QK just
    produced.  Both consumers hit softmax together, so the bubbles expose
    on the tensor-core timeline (arXiv:2402.13499's
    cooperative-vs-ping-pong comparison)."""

    name = "fa3_cooperative"
    scheduling = "cooperative"

    def cta(self, cfg: GPUMachine, w, tiling: FA3Tiling, *, b: int,
            h_q: int, h_kv: int, q_block: int,
            q_base_row: int = 0) -> CTATrace:
        t_m, t_n, D = tiling.t_m, tiling.t_n, w.D
        n_tiles = _n_kv_tiles(w, tiling, q_block, q_base_row)
        bubbles = softmax_bubble_cycles(cfg, t_m, t_n, D)
        n_qk = D // 16
        n_pv = math.ceil(t_n / 16)

        cb = CTABuilder(rings=(Ring("K", tiling.stages),
                               Ring("V", tiling.stages)),
                        n_consumers=2, name=f"b{b}h{h_q}q{q_block}")

        p = cb.wg("producer")
        p.load(TM_Q, (b, q_block * t_m, h_q * D), token="q_ready", tag="Q")
        for j in range(n_tiles):
            p.acquire("K", j)
            p.load(TM_K, (b, j * t_n, h_kv * D), ring="K", slot=j,
                   tag=f"K{j}")
            p.acquire("V", j)
            p.load(TM_V, (b, j * t_n, h_kv * D), ring="V", slot=j,
                   tag=f"V{j}")

        for c in (0, 1):
            t = cb.wg(f"consumer{c}")
            t.wait_token("q_ready")
            for j in range(n_tiles):
                t.wait_tile("K", j)
                # wait=0: the §5.2 WAIT_WG_1 trick (leave the QK group in
                # flight under the softmax) is what the ping-pong barrier
                # schedule buys; cooperative consumers drain first
                t.gemm(m=t_m, n=t_n, steps=n_qk, tag=f"QK{j}", wait=0)
                t.release("K", j)
                t.bubbles(bubbles)
                t.wait_tile("V", j)
                t.gemm(m=t_m, n=D, steps=n_pv, tag=f"PV{j}", wait=0)
                t.release("V", j)
            t.store(TM_O, (b, q_block * t_m, h_q * D), tag="O")

        return cb.finish()


FA3_SPEC = registry.register(FA3PingPong())
FA3_COOPERATIVE_SPEC = registry.register(FA3Cooperative())
