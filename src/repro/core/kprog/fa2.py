"""FA2-style non-specialized attention kernel (the ablation baseline).

No warp specialization (Hopper dissection taxonomy, arXiv:2402.13499):
each of the CTA's two warpgroups issues its **own** K/V tile loads from
inside the compute instruction stream — there is no TMA producer to run
ahead, no shared smem ring between warpgroups (each worker streams through
a private ring, doubling tile traffic), no named-barrier token pass, and
every GEMM drains fully (``wait=0``) before the softmax that consumes it.
Prefetch depth is exactly the ring's stage count: the load for tile
``j + stages`` issues only after tile ``j``'s compute retired its slot.
"""
from __future__ import annotations

import math

from repro.core.engine import CTATrace
from repro.core.kprog import registry
from repro.core.kprog.costs import softmax_bubble_cycles
from repro.core.kprog.fa3 import (TM_K, TM_O, TM_Q, TM_V, FA3PingPong,
                                  FA3Tiling, _n_kv_tiles)
from repro.core.kprog.ir import CTABuilder, Ring, Role
from repro.core.machine import GPUMachine

N_WORKERS = 2      # matches FA3's two consumer warpgroups (equal tiling)


class FA2NonSpecialized(FA3PingPong):
    """Two self-loading worker warpgroups per CTA, no producer.

    Geometry (grid / tmaps / total_ctas) and the DRAM hooks are inherited
    from the FA3 spec — the ablation compares equal launch shapes — only
    the role programs and the L2 hook (doubled tile streams) differ."""

    name = "fa2"
    roles = (Role("worker", N_WORKERS),)
    scheduling = "non-specialized"
    # acquires in flight before the first release; None = the ring's stage
    # count (the deepest legal value — anything larger over-subscribes the
    # ring and is rejected by the kprog verifier)
    prefetch_depth: "int | None" = None

    # -- role programs ---------------------------------------------------
    def cta(self, cfg: GPUMachine, w, tiling: FA3Tiling, *, b: int,
            h_q: int, h_kv: int, q_block: int,
            q_base_row: int = 0) -> CTATrace:
        t_m, t_n, D = tiling.t_m, tiling.t_n, w.D
        stages = tiling.stages
        n_tiles = _n_kv_tiles(w, tiling, q_block, q_base_row)
        bubbles = softmax_bubble_cycles(cfg, t_m, t_n, D)
        n_qk = D // 16
        n_pv = math.ceil(t_n / 16)
        depth = self.prefetch_depth if self.prefetch_depth is not None \
            else stages

        # private K/V rings per worker: no cross-warpgroup smem sharing
        rings = []
        for c in range(N_WORKERS):
            rings += [Ring(f"K{c}", stages), Ring(f"V{c}", stages)]
        cb = CTABuilder(rings=rings, n_consumers=1,
                        name=f"b{b}h{h_q}q{q_block}")

        for c in range(N_WORKERS):
            t = cb.wg(f"worker{c}")
            kr, vr = f"K{c}", f"V{c}"

            def load_tile(j: int) -> None:
                t.acquire(kr, j)
                t.load(TM_K, (b, j * t_n, h_kv * D), ring=kr, slot=j,
                       tag=f"K{j}")
                t.acquire(vr, j)
                t.load(TM_V, (b, j * t_n, h_kv * D), ring=vr, slot=j,
                       tag=f"V{j}")

            # prologue: own Q load + fill the ring
            t.load(TM_Q, (b, q_block * t_m, h_q * D), token=f"q{c}", tag="Q")
            for j in range(min(depth, n_tiles)):
                load_tile(j)
            t.wait_token(f"q{c}")
            for j in range(n_tiles):
                t.wait_tile(kr, j)
                t.gemm(m=t_m, n=t_n, steps=n_qk, tag=f"QK{j}", wait=0)
                t.release(kr, j)
                t.bubbles(bubbles)
                t.wait_tile(vr, j)
                t.gemm(m=t_m, n=D, steps=n_pv, tag=f"PV{j}", wait=0)
                t.release(vr, j)
                if j + depth < n_tiles:       # in-stream prefetch
                    load_tile(j + depth)
            t.store(TM_O, (b, q_block * t_m, h_q * D), tag="O")

        return cb.finish()

    # -- analytical hooks ------------------------------------------------
    def l2_traffic(self, w, t_m: int = 64, tiling=None) -> float:
        """Eq. (2) with per-worker tile streams: each CTA reads Q twice and
        every K/V tile twice (no producer smem sharing)."""
        s_eff = w.S / 2 if w.causal else w.S
        return w.P * w.B * (w.H_kv * w.G) * w.D * (
            3 * w.L + math.ceil(w.L / t_m) * 2 * s_eff * N_WORKERS)
    # DRAM hooks inherited from FA3PingPong: the L2/LRC absorbs the
    # intra-CTA duplicate streams, so Eq. 3/6 apply unchanged


FA2_SPEC = registry.register(FA2NonSpecialized())
