"""Split-KV FlashDecoding decode attention (the serving workload).

Decode-shaped launch (arXiv:2402.13499's decode taxonomy): one new token
per sequence (``w.L == 1``) against a resident KV cache of length ``w.S``.
A plain FA3 launch degenerates to ``B * H_kv * G`` skinny CTAs — far too
few to fill the machine — so the KV axis is split across CTAs instead:

  * **split CTAs** — one per (batch, kv-head, split): a TMA producer
    streams the split's K/V chunk, a single consumer runs T_M = G row
    GEMMs (the G grouped query heads of one KV head stacked as MMA rows —
    each is a 1-row q block) and stores a partial fp32 O tile + LSE to a
    scratch buffer;
  * **reduction CTAs** — one per (batch, kv-head): load the ``n_split``
    partials, rescale/accumulate them on CUDA cores, store the final O.

The engine has no inter-CTA barrier; reduction CTAs are launched after all
split CTAs, which under head-major rasterization puts each reduction a full
wave behind its producers (exact cross-CTA ordering is a known
approximation, documented in docs/kernels.md).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.core.engine import CTATrace
from repro.core.isa import TensorMap
from repro.core.kprog import registry
from repro.core.kprog.costs import combine_cycles, softmax_bubble_cycles
from repro.core.kprog.ir import CTABuilder, KernelSpec, Ring, Role
from repro.core.machine import GPUMachine

TM_Q, TM_K, TM_V, TM_O, TM_PART = 0, 1, 2, 3, 4
PART_P = 4        # partials are fp32


@dataclass(frozen=True)
class SplitKVTiling:
    t_n: int = 128         # kv rows per tile
    stages: int = 2        # ring-buffer stages for K and V each
    n_split: int = 4       # KV splits (split CTAs per (batch, kv head))
    precision: int = 2     # fp16 activations


class SplitKVDecode(KernelSpec):
    """FlashDecoding: KV split across CTAs + reduction epilogue."""

    name = "splitkv_decode"
    roles = (Role("producer"), Role("consumer"), Role("reducer"))
    scheduling = "split-kv"

    def default_tiling(self) -> SplitKVTiling:
        return SplitKVTiling()

    def probe_workload(self):
        """Decode-shaped probe: one query token, cache long enough that
        every split streams 3 tiles (> stages, so the ring wraps)."""
        from repro.configs.llama3 import AttnWorkload
        return AttnWorkload(name=f"{self.name}-probe", B=1, L=1, S=1536,
                            H_kv=1, G=2, D=64)

    # -- geometry --------------------------------------------------------
    def grid(self, w, tiling: SplitKVTiling):
        for b in range(w.B):
            for hkv in range(w.H_kv):
                for s in range(tiling.n_split):
                    yield dict(b=b, h_kv=hkv, split=s)
        for b in range(w.B):
            for hkv in range(w.H_kv):
                yield dict(b=b, h_kv=hkv, split=-1)      # reduction CTA

    def total_ctas(self, w, tiling: SplitKVTiling = None) -> int:
        tiling = tiling if tiling is not None else self.default_tiling()
        return w.B * w.H_kv * (tiling.n_split + 1)

    def tmaps(self, w, tiling: SplitKVTiling) -> Dict[int, TensorMap]:
        """Q/O are (B, 1, H_q*D) single-token tensors; partials live in a
        (B, n_split, H_kv*G*D) fp32 scratch past the O tensor."""
        P, D, G = tiling.precision, w.D, w.G
        H_q = w.H_kv * w.G
        sz_q = w.B * H_q * D * P
        sz_kv = w.B * w.S * w.H_kv * D * P
        base_o = sz_q + 2 * sz_kv
        base_part = base_o + sz_q
        row = w.H_kv * G * D
        return {
            TM_Q: TensorMap(TM_Q, 0, (w.B, 1, H_q * D),
                            (H_q * D * P, H_q * D * P, P),
                            (1, 1, G * D), P),
            TM_K: TensorMap(TM_K, sz_q, (w.B, w.S, w.H_kv * D),
                            (w.S * w.H_kv * D * P, w.H_kv * D * P, P),
                            (1, tiling.t_n, D), P),
            TM_V: TensorMap(TM_V, sz_q + sz_kv, (w.B, w.S, w.H_kv * D),
                            (w.S * w.H_kv * D * P, w.H_kv * D * P, P),
                            (1, tiling.t_n, D), P),
            TM_O: TensorMap(TM_O, base_o, (w.B, 1, H_q * D),
                            (H_q * D * P, H_q * D * P, P),
                            (1, 1, G * D), P),
            TM_PART: TensorMap(TM_PART, base_part,
                               (w.B, tiling.n_split, row),
                               (tiling.n_split * row * PART_P,
                                row * PART_P, PART_P),
                               (1, 1, G * D), PART_P),
        }

    # -- role programs ---------------------------------------------------
    def cta(self, cfg: GPUMachine, w, tiling: SplitKVTiling, *, b: int,
            h_kv: int, split: int) -> CTATrace:
        if split < 0:
            return self._reduction_cta(cfg, w, tiling, b=b, h_kv=h_kv)
        return self._split_cta(cfg, w, tiling, b=b, h_kv=h_kv, split=split)

    def _split_cta(self, cfg, w, tiling, *, b, h_kv, split) -> CTATrace:
        t_n, D, G = tiling.t_n, w.D, w.G
        chunk = math.ceil(w.S / tiling.n_split)
        lo = split * chunk
        hi = min(w.S, lo + chunk)
        n_tiles = max(0, math.ceil((hi - lo) / t_n))
        bubbles = softmax_bubble_cycles(cfg, G, t_n, D)
        n_qk = D // 16
        n_pv = math.ceil(t_n / 16)

        cb = CTABuilder(rings=(Ring("K", tiling.stages),
                               Ring("V", tiling.stages)),
                        n_consumers=1, name=f"b{b}h{h_kv}s{split}")

        p = cb.wg("producer")
        p.load(TM_Q, (b, 0, h_kv * G * D), token="q_ready", tag="Q")
        for j in range(n_tiles):
            row = lo + j * t_n
            p.acquire("K", j)
            p.load(TM_K, (b, row, h_kv * D), ring="K", slot=j, tag=f"K{j}")
            p.acquire("V", j)
            p.load(TM_V, (b, row, h_kv * D), ring="V", slot=j, tag=f"V{j}")

        t = cb.wg("consumer")
        t.wait_token("q_ready")
        for j in range(n_tiles):
            t.wait_tile("K", j)
            # wait=0: a single consumer has no opposite-phase warpgroup to
            # pipeline behind (same rule as fa3_cooperative) — the softmax
            # consumes the scores this QK just produced
            t.gemm(m=G, n=t_n, steps=n_qk, tag=f"QK{j}", wait=0)
            t.release("K", j)
            t.bubbles(bubbles)
            t.wait_tile("V", j)
            t.gemm(m=G, n=D, steps=n_pv, tag=f"PV{j}", wait=0)
            t.release("V", j)
        t.store(TM_PART, (b, split, h_kv * G * D), tag="Opart")

        return cb.finish()

    def _reduction_cta(self, cfg, w, tiling, *, b, h_kv) -> CTATrace:
        G, D = w.G, w.D
        cb = CTABuilder(n_consumers=1, name=f"b{b}h{h_kv}red")
        r = cb.wg("reducer")
        for s in range(tiling.n_split):
            r.load(TM_PART, (b, s, h_kv * G * D), token="parts",
                   tag=f"P{s}")
        for _ in range(tiling.n_split):
            r.wait_token("parts")
        r.bubbles(combine_cycles(cfg, G, D, tiling.n_split))
        r.store(TM_O, (b, 0, h_kv * G * D), tag="O")
        return cb.finish()

    # -- analytical hooks ------------------------------------------------
    def l2_traffic(self, w, t_m: int = 64, tiling=None) -> float:
        """Q re-read per split CTA + KV streamed once + partial write/read
        + final O write (``t_m`` is not a decode knob; the split count
        comes from the tiling)."""
        tl = tiling if tiling is not None else self.default_tiling()
        gd = w.H_kv * w.G * w.D
        q = w.P * w.B * tl.n_split * gd
        kv = 2 * w.P * w.B * w.H_kv * w.S * w.D
        parts = 2 * PART_P * w.B * tl.n_split * gd
        o = w.P * w.B * gd
        return q + kv + parts + o

    def dram_ideal(self, w) -> float:
        # Q once (L2 serves the split re-reads), KV once, O once
        return w.P * w.B * w.D * (2 * w.H_kv * w.G + 2 * w.H_kv * w.S)

    def ramp_bubble_cycles(self, cfg, w, t_m: int, t_n: int) -> int:
        # decode's compute block is G rows (one per grouped q head), not
        # the prefill T_M tile
        return softmax_bubble_cycles(cfg, w.G, t_n, w.D)

    def dram_real(self, w, t_m: int, n_sm: int, o_limit: int,
                  tiling=None) -> float:
        """Single pass over the cache — but every partial-store line is a
        write-allocate miss that fetches from DRAM before dirtying."""
        tl = tiling if tiling is not None else self.default_tiling()
        parts = PART_P * w.B * tl.n_split * w.H_kv * w.G * w.D
        o_fill = w.P * w.B * w.H_kv * w.G * w.D
        return self.dram_ideal(w) + parts + o_fill


SPLITKV_DECODE_SPEC = registry.register(SplitKVDecode())
