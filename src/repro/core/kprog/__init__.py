"""Kernel-program IR: declarative warp-specialization layer + registry.

``repro.core.kprog.ir`` defines the IR (roles, rings, named tokens) and the
``KernelSpec.build()`` lowering to engine traces; ``registry`` maps kernel
names to registered specs (``fa3``, ``fa3_cooperative``, ``fa2``,
``splitkv_decode``) and statically verifies each one at resolve time;
``verify`` is the legality oracle itself (deadlock freedom, protocol
discipline, hazards — see docs/verification.md).  See docs/kernels.md.
"""
from repro.core.kprog.ir import CTABuilder, KernelSpec, Ring, Role, WGProgram
from repro.core.kprog.registry import available, get, register
from repro.core.kprog.verify import (Finding, KernelVerificationError,
                                     VerifyReport, verify_cta, verify_ctas,
                                     verify_spec)

__all__ = ["CTABuilder", "KernelSpec", "Ring", "Role", "WGProgram",
           "available", "get", "register",
           "Finding", "KernelVerificationError", "VerifyReport",
           "verify_cta", "verify_ctas", "verify_spec"]
