"""Sim-FA core: event-driven, WarpGroup-granular cycle-level engine.

Implements the paper's Algorithm 1:
  * each WarpGroup is a *logical thread* with a single instruction flow;
  * the Scheduler dispatches logical threads (grouped in CTAs) to physical
    SM slots under the occupancy limit, and plays warp-scheduler (GTO)
    among resident threads;
  * the Frontend issues in order, executes out of order: async ops are
    handed to the TMA / TensorCore engines, waits with unmet conditions
    roll the PC back and park the thread on a waiter list (AEQ);
  * mbarriers, pipeline stages (producer_acquire / consumer_release),
    WGMMA commit groups, TMA store groups and named barriers are modeled
    in full — the paper found incomplete barrier modeling breaks overlap
    estimation (§4.1).

The default run loop (``scheduler="event"``) is a true discrete-event
loop: time jumps straight to the next interesting cycle — the event-queue
head or the next cycle any SM can issue — and *nothing scans threads*.
Each SM keeps a maintained issue-eligible ready queue (READY, non-busy,
non-done threads in GTO dispatch order), ``busy_until`` sleepers park on
coalesced per-SM timer events (``EventQueue.wake_at``), and the active-SM
set is a flag-guarded min-heap drained in ascending id order.  That is
what makes a Python implementation viable where the paper uses C++.

Scheduling is *condition-indexed*: a thread whose wait condition fails is
parked on a waiter list keyed by exactly what it waits for — an mbarrier
``(cta, sid)`` signal, a stage-release count, its own WGMMA/TMA group
drain, a named-barrier arrival, a tensor-core buffer slot, or a
``busy_until`` timer — and each completion event wakes only the threads
whose condition just became satisfiable.  A woken thread's condition is
always re-validated at issue time in ``SM.step``, so a spurious wake is
harmless; the wake index only has to never *miss* a wake.  Two fallback
schedulers survive for equivalence testing and deadlock safety:
``scheduler="waiter"`` (the condition-indexed scan loop this PR's event
loop grew out of) and ``scheduler="broadcast"`` / ``broadcast_wake=True``
(every completion re-marks every resident thread READY and rescans).  All
three are cycle-for-cycle *bit-exact* — identical ``stats()`` dicts and
event streams (see ``tests/test_engine_equiv.py``).
"""
from __future__ import annotations

from bisect import insort
from collections import deque
from dataclasses import dataclass
from operator import attrgetter
from typing import Dict, List, Optional, Tuple

from repro.core import isa
from repro.core.isa import Instr, TensorMap
from repro.core.machine import GPUMachine
from repro.core.memory import EventQueue, build_memory
from repro.obs.labels import make_label

READY, STALLED, DONE = 0, 1, 2

_ORDER = attrgetter("order")    # GTO dispatch-order sort key

# ops whose issue condition can fail (everything else issues unconditionally;
# WGMMA — the hottest op — is special-cased ahead of the set probe)
_BLOCKING = frozenset((isa.MB_WAIT, isa.ACQUIRE_STAGE, isa.WGMMA_WAIT,
                       isa.TMA_WAIT, isa.BAR_WAIT))


@dataclass
class CTATrace:
    """One thread block: a list of WarpGroup instruction traces.

    ``roles`` optionally names each warpgroup's declared role instance
    (e.g. ``["producer", "consumer0", "consumer1"]``, from the kernel IR);
    thread labels — and therefore stall-attribution keys — use these names
    instead of positional ``wg{i}`` indices when present.

    ``rings`` optionally maps each declared ring buffer to its stage sids
    (``{"K": (0, 2), "V": (1, 3)}``, from the kernel IR) — pure metadata
    the engine never reads; the counter sink uses it to derive per-ring
    occupancy depth from the mbarrier/release state.

    ``tokens`` (name -> sid) and ``acq_slots`` (per-WG ``{instr index:
    (ring, raw slot)}`` for ACQUIRE_STAGE instructions) are further
    IR-metadata riders consumed by the static verifier
    (``repro.core.kprog.verify``) — sid-space collision checks need the
    token allocation, and slot-aliasing witnesses need the pre-wrap slot
    numbers that lowering folds into sids."""
    wgs: List[List[Instr]]
    n_consumers: int = 2
    name: str = ""
    roles: Optional[List[str]] = None
    rings: Optional[Dict[str, Tuple[int, ...]]] = None
    tokens: Optional[Dict[str, int]] = None
    acq_slots: Optional[List[Dict[int, Tuple[str, int]]]] = None


class WGThread:
    __slots__ = ("trace", "trace_len", "pc", "state", "cta", "wg_id", "sm",
                 "busy_until", "wgmma_groups", "tma_groups", "wgmma_out",
                 "tma_out", "mb_expected", "acq_count", "label", "parked",
                 "order", "in_ready", "mma_pending")

    def __init__(self, trace, cta, wg_id):
        self.trace = trace
        self.trace_len = len(trace)
        self.pc = 0
        self.state = READY
        self.cta = cta
        self.wg_id = wg_id
        self.sm = None
        self.busy_until = 0
        # per-WG async group bookkeeping: gid -> [issued, completed, committed]
        self.wgmma_groups: Dict[int, List] = {}
        self.tma_groups: Dict[int, List] = {}
        # committed-but-incomplete group ids; len() is the outstanding count
        # the drain waits test, so WGMMA_WAIT/TMA_WAIT checks are O(1)
        self.wgmma_out: set = set()
        self.tma_out: set = set()
        # lazy-completion FIFO (event scheduler): (cycle, gid) per in-pipe
        # WGMMA, applied to wgmma_groups on observation instead of per-event
        self.mma_pending: deque = deque()
        self.mb_expected: Dict[int, int] = {}
        self.acq_count: Dict[int, int] = {}
        self.label = ""
        self.parked = False      # registered on a keyed waiter list
        self.order = (0, wg_id)  # GTO dispatch-order key, set by CTA
        self.in_ready = False    # member of its SM's issue-eligible queue

    def done(self):
        return self.pc >= self.trace_len


class CTA:
    __slots__ = ("trace", "threads", "mbarrier", "stage_releases",
                 "bar_arrivals", "n_consumers", "idx", "done_wgs",
                 "mb_waiters", "stage_waiters", "bar_waiters")

    def __init__(self, trace: CTATrace, idx: int):
        self.trace = trace
        self.idx = idx
        self.n_consumers = trace.n_consumers
        self.threads = [WGThread(t, self, i) for i, t in enumerate(trace.wgs)]
        roles = trace.roles
        for i, t in enumerate(self.threads):
            role = roles[i] if roles and i < len(roles) else f"wg{i}"
            t.label = make_label(idx, role)
            t.order = (idx, i)
        self.mbarrier: Dict[int, int] = {}        # sid -> completed signals
        self.stage_releases: Dict[int, int] = {}  # sid -> consumer releases
        self.bar_arrivals: Dict[int, int] = {}    # bid -> arrivals
        self.done_wgs = 0
        # condition-indexed waiter lists (waiter-mode scheduler only)
        self.mb_waiters: Dict[int, List[WGThread]] = {}
        self.stage_waiters: Dict[int, List[WGThread]] = {}
        self.bar_waiters: Dict[int, List[WGThread]] = {}


class TensorCoreEngine:
    """Single tensor-core pipeline + WGMMA issue buffer per SM (§4.2)."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.buffer: deque = deque()   # (WGThread, Instr, nid)
        # Defensive waiter list: _pump pops synchronously on every push
        # (serialization is modeled via busy_until), so with the current
        # pipeline model can_accept() never fails and nothing parks here.
        # The list exists so a future occupancy-accurate buffer model can't
        # introduce a missed-wake deadlock on the WGMMA stall path.
        self.waiters: List[WGThread] = []   # threads parked on a buffer slot
        self.busy_until = 0
        self.busy_cycles = 0
        self.faults = sm.engine.faults
        self._div = cfg.wgmma_n_cycles_divisor
        self._dur_memo: Dict[int, int] = {}   # ins.n -> pipeline cycles
        # Lazy completion mode (event scheduler, no sanitizer): a WGMMA's
        # completion only mutates its own thread's group counters and can
        # only wake that thread from its own WGMMA_WAIT, so instead of one
        # EventQueue callback per WGMMA the completion is queued on
        # th.mma_pending and folded in at the few sites that observe group
        # state; a stalled drain wait gets ONE wake event at its exactly
        # computable satisfaction cycle (the pipe is serial, so pending
        # completion cycles are known at stall time).
        self.lazy = (sm.engine.scheduler == "event"
                     and sm.engine.sanitizer is None)

    def can_accept(self) -> bool:
        return len(self.buffer) < self.cfg.wgmma_issue_buffer

    def _apply(self, th: WGThread, now: int):
        """Fold every lazily queued completion at or before ``now`` into the
        thread's group counters (the work _complete does eagerly)."""
        pend = th.mma_pending
        if not pend:
            return
        groups = th.wgmma_groups
        out = th.wgmma_out
        while pend and pend[0][0] <= now:
            _, gid = pend.popleft()
            g = groups[gid]
            g[1] += 1
            if g[2] and g[1] >= g[0]:
                out.discard(gid)

    def push(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        pend = th.mma_pending
        if pend and pend[0][0] <= cycle:
            self._apply(th, cycle)     # reuse check below reads g[1]
        groups = th.wgmma_groups
        g = groups.get(ins.gid)
        if g is None:                  # .get avoids setdefault's list alloc
            groups[ins.gid] = g = [0, 0, False]
        g[0] += 1
        if g[2] and g[1] == g[0] - 1:
            # a committed, fully drained group id got reused: outstanding again
            th.wgmma_out.add(ins.gid)
        if self.buffer:
            self.buffer.append((th, ins, nid))
            self._pump(cycle)
            return
        # fast path: the buffer is empty (the synchronous pop in _pump keeps
        # it so), so this op heads straight into the pipe — same arithmetic
        # as _pump without the deque round-trip, with the N->cycles mapping
        # memoized (the divisor is frozen per machine config)
        start = self.busy_until
        if start < cycle:
            start = cycle
        dur = ins.cycles
        if dur <= 0:
            memo = self._dur_memo
            dur = memo.get(ins.n)
            if dur is None:
                dur = max(1, int(round(ins.n / self._div)))
                memo[ins.n] = dur
        fl = self.faults
        if fl is not None:
            dur = fl.stretch(start, self.sm.sm_id, dur)
        self.busy_until = start + dur
        self.busy_cycles += dur
        if self.sm.tracer is not None:
            self.sm.tracer.on_mma(nid, th, ins, start, start + dur)
        if self.lazy:
            th.mma_pending.append((start + dur, ins.gid))
        else:
            self.evq.push(start + dur, self._complete, th, ins.gid)

    def _pump(self, cycle: int):
        if not self.buffer:
            return
        start = max(cycle, self.busy_until)
        th, ins, nid = self.buffer.popleft()
        # GPU mode: FP16 m64nNk16 completes in ~N/2 cycles (paper §4.2);
        # TPU mode: the tracegen precomputes MXU cycles into ins.cycles.
        dur = ins.cycles if ins.cycles > 0 else max(
            1, int(round(ins.n / self.cfg.wgmma_n_cycles_divisor)))
        fl = self.faults
        if fl is not None:
            dur = fl.stretch(start, self.sm.sm_id, dur)
        self.busy_until = start + dur
        self.busy_cycles += dur
        if self.sm.tracer is not None:
            self.sm.tracer.on_mma(nid, th, ins, start, start + dur)
        if self.lazy:
            th.mma_pending.append((start + dur, ins.gid))
        else:
            self.evq.push(start + dur, self._complete, th, ins.gid)

    def drain_wake_cycle(self, th: WGThread, ins: Instr) -> Optional[int]:
        """Cycle at which ``th``'s WGMMA_WAIT drain condition flips true.

        The TC pipe is strictly serial, so the pending completions' cycles
        and group ids are already determined; walk them in order, retiring
        outstanding groups <= ins.gid, until enough have drained.  Returns
        None if pending completions cannot satisfy the wait (then no
        eager completion event would have woken the thread either)."""
        gid = ins.gid
        groups = th.wgmma_groups
        rem: Dict[int, int] = {}
        for g_ in th.wgmma_out:
            if g_ <= gid:
                g = groups[g_]
                rem[g_] = g[0] - g[1]
        need = len(rem) - ins.n
        if need <= 0:
            return None
        for t, g_ in th.mma_pending:
            r = rem.get(g_)
            if r is not None:
                r -= 1
                rem[g_] = r
                if r == 0:
                    need -= 1
                    if need == 0:
                        return t
        return None

    def _drain_wake(self, th: WGThread):
        """Scheduled wake for a lazily tracked WGMMA_WAIT stall."""
        self._apply(th, self.sm.engine.cycle)
        self.sm.notify_group(th)

    def _complete(self, th: WGThread, gid: int):
        g = th.wgmma_groups[gid]
        g[1] += 1
        if g[2] and g[1] >= g[0]:
            th.wgmma_out.discard(gid)
        # inlined notify_group guard: the issuing thread is usually still
        # running (not stalled on its own drain), so skip the call entirely
        sm = self.sm
        if sm.broadcast:
            sm.wake_all()
        elif th.state == STALLED and not th.parked:
            sm.notify_group(th)
        if self.buffer:
            self._pump(self.busy_until)
        if self.waiters:
            sm.notify_tc()


class TMAEngine:
    """Per-SM TMA engine: descriptor setup, HW address generation with line
    dedup, bounded in-flight lines, mbarrier signaling (§4.3).

    The line path is *batched*: each cycle's issuable lines go to the LRC in
    one ``request_many`` call sharing a single per-job completion callback
    (a shared counter), instead of one closure per line; finished jobs are
    retired at completion time, so ``jobs`` only ever holds live jobs."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm, lrc, tmaps):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.eng = sm.engine
        self.lrc = lrc
        self.tmaps = tmaps
        self.faults = sm.engine.faults
        # tile fidelity: the front end is a TileMemory and whole tiles are
        # charged as single bulk transactions (no per-line issue machinery)
        self._tile_mem = lrc if sm.engine.mem_fidelity == "tile" else None
        # frozen-config hot constants, hoisted off the issue path
        self._lpc = cfg.tma_lines_per_cycle
        self._cap = cfg.tma_max_inflight_lines
        self.jobs: List[dict] = []    # live jobs, round-robin issue order
        self.lines_issued = 0
        self.lines_queued = 0         # un-issued lines across all live jobs
        self._kick_scheduled = False
        self._issue_cycle = -1
        self._issued_in_cycle = 0

    def _tile_lines(self, ins: Instr):
        """Hardware address generation, cached per (map, origin): CTAs of the
        same KV head stream identical K/V tiles (Eq. 5/6 reuse structure).
        Caching starts on the *second* encounter so per-CTA-unique tiles
        (Q loads, O stores) cost a set entry, not a retained line list."""
        eng = self.sm.engine
        key = (ins.map_id, ins.origin)
        lines = eng.tile_cache.get(key)
        if lines is None:
            tm: TensorMap = self.tmaps[ins.map_id]
            lines = tm.tile_lines(ins.origin, self.cfg.line_bytes,
                                  dedup=self.cfg.tma_dedup)
            seen = eng.tile_seen
            if key in seen:
                eng.tile_cache[key] = lines
            else:
                seen.add(key)
        return lines

    def submit_load(self, cycle: int, th: WGThread, ins: Instr,
                    nid: int = -1):
        lines = self._tile_lines(ins)
        # Fig. 2: non-tensor bulk requests bypass the descriptor cache and
        # TensorMap setup path -> only the common launch latency applies.
        setup = self.cfg.tma_launch_latency + (
            0 if ins.bulk else self.cfg.tma_tmap_setup_latency)
        fl = self.faults
        if fl is not None:
            setup += fl.tma_extra()
        if self._tile_mem is not None:
            self._submit_tile(cycle, th, ins, nid, False, setup, lines)
            return
        job = {"lines": deque(lines), "left": len(lines), "th": th,
               "sid": ins.sid, "write": False, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        job["done"] = self._make_done(job)
        self.evq.push(cycle + setup, self._start, job)

    def submit_store(self, cycle: int, th: WGThread, ins: Instr,
                     nid: int = -1):
        lines = self._tile_lines(ins)
        g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
        g[0] += 1
        if g[2] and g[1] == g[0] - 1:
            th.tma_out.add(ins.gid)
        # stores bypass the TensorMap setup path only when bulk (Fig. 2);
        # FA3's O store uses a TensorMap -> full setup
        setup = self.cfg.tma_launch_latency + self.cfg.tma_tmap_setup_latency
        fl = self.faults
        if fl is not None:
            setup += fl.tma_extra()
        if self._tile_mem is not None:
            self._submit_tile(cycle, th, ins, nid, True, setup, lines)
            return
        job = {"lines": deque(lines), "left": len(lines), "th": th,
               "gid": ins.gid, "write": True, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        job["done"] = self._make_done(job)
        self.evq.push(cycle + setup, self._start, job)

    # -- tile fidelity: one bulk transaction + one completion event per job
    def _submit_tile(self, cycle: int, th: WGThread, ins: Instr, nid: int,
                     write: bool, setup: int, lines):
        job = {"lines": (), "left": 0, "th": th, "write": write,
               "tag": ins.tag, "t0": cycle, "inflight": len(lines),
               "nid": nid, "setup": setup}
        if write:
            job["gid"] = ins.gid
        else:
            job["sid"] = ins.sid
        self.lines_issued += len(lines)
        self.evq.push(cycle + setup, self._start_tile, job, lines)

    def _start_tile(self, job, lines):
        self.jobs.append(job)    # live while in flight: counter sink samples
        t = self._tile_mem.transact(self.eng.cycle, lines, self.sm.sm_id,
                                    job["write"])
        fl = self.eng.faults
        if fl is not None:
            d = fl.finish_delay()
            if d:
                t += d
        self.evq.push(t, self._retire_tile, job)

    def _retire_tile(self, job):
        job["inflight"] = 0
        self._finish(job)

    def _make_done(self, job):
        """One shared completion callback per job — the LRC invokes it once
        per finished line (shared counter, no per-line closures).

        The steady-state path is *targeted*: between issue events, only the
        job whose line just completed can have both queued lines and
        in-flight room (any other job with room would already have issued
        when capacity last appeared), so — mid-cycle, budget permitting —
        its replacement line is issued directly instead of re-scanning all
        live jobs.  The full scan is kept for cycle boundaries, where the
        budget resets and rate-limited jobs must issue in legacy order."""
        lrc = self.lrc
        eng = self.eng
        sm_id = self.sm.sm_id
        def done():
            job["left"] -= 1
            if job["left"] == 0:
                fl = eng.faults
                d = fl.finish_delay() if fl is not None else 0
                if d:
                    # delayed async-completion delivery: the last line has
                    # landed but the mbarrier signal / group retirement only
                    # becomes visible d cycles later.  The job stays in
                    # self.jobs (empty line deque -> _issue skips it).
                    self.evq.push(eng.cycle + d, self._finish, job)
                else:
                    self._finish(job)
                if (self.lines_queued and self._kick_scheduled
                        and eng.cycle > self._issue_cycle):
                    self._issue(eng.cycle)
                return
            lines = job["lines"]
            now = eng.cycle
            if now > self._issue_cycle:
                if self._kick_scheduled:
                    # an unfired carry-over kick covers rate-limited jobs
                    # that must issue first, in legacy scan order
                    job["inflight"] -= 1
                    if self.lines_queued:
                        self._issue(now)
                    return
                if not lines:
                    job["inflight"] -= 1
                    return
                # fresh cycle, no carry-over work: the budget resets and
                # this job is the only issue-eligible one
                self._issue_cycle = now
                self._issued_in_cycle = 1
                self.lines_issued += 1
                self.lines_queued -= 1
                lrc.request_one(now, lines.popleft(), sm_id, done,
                                job["write"])
                return
            if lines and self._issued_in_cycle < self._lpc:
                # targeted refill: this job freed exactly one slot, and no
                # other job can be issue-eligible mid-cycle (see above)
                self._issued_in_cycle += 1
                self.lines_issued += 1
                self.lines_queued -= 1
                lrc.request_one(now, lines.popleft(), sm_id, done,
                                job["write"])
                return
            job["inflight"] -= 1
            if lines and not self._kick_scheduled:
                # budget spent with lines still queued: carry over
                self._kick_scheduled = True
                self.evq.push(now + 1, self._kick)
        return done

    def _start(self, job):
        self.jobs.append(job)
        self.lines_queued += len(job["lines"])
        self._issue(self._now())

    def _now(self):
        return self.eng.cycle

    def _issue(self, cycle: int):
        """Issue up to tma_lines_per_cycle lines this cycle, round-robin over
        in-flight TMA ops; max_inflight_lines bounds each op's outstanding
        lines (several ops stream concurrently through the ring buffer)."""
        if cycle > self._issue_cycle:
            self._issue_cycle = cycle
            self._issued_in_cycle = 0
        budget = self._lpc - self._issued_in_cycle
        if budget > 0 and self.lines_queued:
            inflight_cap = self._cap
            request_one = self.lrc.request_one
            sm_id = self.sm.sm_id
            for job in self.jobs:
                if budget <= 0:
                    break
                lines = job["lines"]
                take = budget
                n = len(lines)
                if n < take:
                    take = n
                room = inflight_cap - job["inflight"]
                if room < take:
                    take = room
                if take <= 0:
                    continue
                job["inflight"] += take
                self.lines_issued += take
                self.lines_queued -= take
                self._issued_in_cycle += take
                budget -= take
                done_cb = job["done"]
                write = job["write"]
                for _ in range(take):
                    request_one(cycle, lines.popleft(), sm_id, done_cb,
                                write)
        # rate-limited this cycle with lines still issuable: kick next cycle.
        # (inflight-capped jobs are re-kicked by their done() callbacks)
        if (self.lines_queued and not self._kick_scheduled
                and self._issued_in_cycle >= self._lpc):
            cap = self._cap
            for j in self.jobs:
                if j["lines"] and j["inflight"] < cap:
                    self._kick_scheduled = True
                    self.evq.push(cycle + 1, self._kick)
                    break

    def _kick(self):
        self._kick_scheduled = False
        self._issue(self._now())

    def _finish(self, job):
        th: WGThread = job["th"]
        signal_n = 0
        if job["write"]:
            g = th.tma_groups[job["gid"]]
            g[1] += 1
            if g[2] and g[1] >= g[0]:
                th.tma_out.discard(job["gid"])
        else:
            cta = th.cta
            cta.mbarrier[job["sid"]] = cta.mbarrier.get(job["sid"], 0) + 1
            signal_n = cta.mbarrier[job["sid"]]
        if self.sm.tracer is not None:
            self.sm.tracer.on_tma(
                job["nid"], th, write=job["write"], tag=job["tag"],
                t0=job["t0"], t1=self._now(), fixed=job["setup"],
                sid=job.get("sid", -1), gid=job.get("gid", -1),
                signal_n=signal_n)
        self.jobs.remove(job)
        if job["write"]:
            self.sm.notify_group(th)
        else:
            self.sm.notify_mb(th.cta, job["sid"])


class SM:
    def __init__(self, sm_id: int, cfg: GPUMachine, engine):
        self.sm_id = sm_id
        self.cfg = cfg
        self.engine = engine
        self.evq = engine.evq
        self.tracer = engine.tracer
        self.broadcast = engine.broadcast_wake
        self.event = engine.scheduler == "event"
        self.san = engine.sanitizer
        self.faults = engine.faults
        self.ctas: List[CTA] = []
        self._threads: List[WGThread] = []   # flat resident non-DONE threads
        # event-mode issue-eligible queue: READY, non-busy, non-done threads
        # in GTO dispatch order (sorted by WGThread.order); kept exact by the
        # state transitions in step()/_execute()/wakes, so neither step() nor
        # the run loop ever scans blocked threads
        self._ready: List[WGThread] = []
        # event-mode busy-timer park: wake cycle -> threads sleeping on
        # busy_until (BUBBLES), woken by one coalesced evq.wake_at per cycle
        self._timers: Dict[int, List[WGThread]] = {}
        self.tc = TensorCoreEngine(cfg, self.evq, self)
        self.tma = TMAEngine(cfg, self.evq, self, engine.lrc, engine.tmaps)
        self.current: Optional[WGThread] = None   # GTO greedy pointer
        self.issue_cycles = 0
        # hot-loop constants (step() runs once per issuing SM per cycle)
        self._iw = cfg.issue_width
        self._tc_cap = cfg.wgmma_issue_buffer

    # ------------------------------------------------------------------
    def threads(self):
        return self._threads

    def _rebuild_threads(self):
        self._threads = [th for cta in self.ctas for th in cta.threads
                         if th.state != DONE]

    def wake_all(self):
        self.engine.mark_active(self)

    def _timer_fire(self, cycle: int):
        """Coalesced busy_until timer (event mode): return every thread whose
        bubble drains at ``cycle`` to the ready queue.  Threads that went
        DONE while draining (trace ended on the bubble) are skipped — their
        retirement is a separate _finish_thread event."""
        for th in self._timers.pop(cycle, ()):
            if th.state == READY:
                th.in_ready = True
                insort(self._ready, th, key=_ORDER)
        if self._ready:
            self.engine.mark_active(self)

    def has_slot(self) -> bool:
        return len(self.ctas) < self.cfg.occupancy_limit

    # ------------------------------------------------------------------
    # condition checks for blocking instructions
    def _cond_met(self, th: WGThread, ins: Instr) -> bool:
        op = ins.op
        if op == isa.WGMMA:             # hottest op: checked first
            return self.tc.can_accept()
        if op not in _BLOCKING:         # non-blocking ops: one set probe
            return True
        cta = th.cta
        if op == isa.MB_WAIT:
            need = th.mb_expected.get(ins.sid, 0) + 1
            return cta.mbarrier.get(ins.sid, 0) >= need
        if op == isa.ACQUIRE_STAGE:
            use = th.acq_count.get(ins.sid, 0)
            if use == 0:
                return True
            return cta.stage_releases.get(ins.sid, 0) >= use * cta.n_consumers
        if op == isa.WGMMA_WAIT:
            pend = th.mma_pending
            if pend and pend[0][0] <= self.engine.cycle:
                self.tc._apply(th, self.engine.cycle)
            out = th.wgmma_out
            if len(out) <= ins.n:       # O(1) fast path: total outstanding
                return True
            gid = ins.gid
            return sum(1 for g in out if g <= gid) <= ins.n
        if op == isa.TMA_WAIT:
            out = th.tma_out
            if len(out) <= ins.n:
                return True
            gid = ins.gid
            return sum(1 for g in out if g <= gid) <= ins.n
        # BAR_WAIT (the only remaining member of _BLOCKING)
        return cta.bar_arrivals.get(ins.bid, 0) >= ins.n

    # ------------------------------------------------------------------
    # waiter index: park / targeted wake (waiter-mode scheduler)
    def _park(self, th: WGThread, ins: Instr):
        """Register a freshly stalled thread under its wake condition.
        WGMMA_WAIT/TMA_WAIT drain only on this thread's own group
        completions, so those are probed directly (no list needed)."""
        if th.parked:
            return
        op = ins.op
        if op == isa.MB_WAIT:
            th.cta.mb_waiters.setdefault(ins.sid, []).append(th)
        elif op == isa.ACQUIRE_STAGE:
            th.cta.stage_waiters.setdefault(ins.sid, []).append(th)
        elif op == isa.BAR_WAIT:
            th.cta.bar_waiters.setdefault(ins.bid, []).append(th)
        elif op == isa.WGMMA:
            self.tc.waiters.append(th)
        else:                       # WGMMA_WAIT / TMA_WAIT: probed via
            # notify_group, not list-parked.  Under lazy completions a
            # WGMMA_WAIT gets its one wake event at the computed drain cycle
            # (TMA_WAIT drains stay eventful via TMAEngine._finish).
            if op == isa.WGMMA_WAIT and self.tc.lazy:
                t = self.tc.drain_wake_cycle(th, ins)
                if t is not None:
                    self.evq.push(t, self.tc._drain_wake, th)
            return
        th.parked = True

    def _drain_waiters(self, lst: List[WGThread]):
        """Wake every parked thread whose condition now holds."""
        woke = False
        kept = []
        event = self.event
        for th in lst:
            if self._cond_met(th, th.trace[th.pc]):
                th.parked = False
                th.state = READY
                if event:
                    th.in_ready = True
                    insort(self._ready, th, key=_ORDER)
                woke = True
            else:
                kept.append(th)
        lst[:] = kept
        if woke:
            self.engine.mark_active(self)

    def _notify_keyed(self, waiters: Dict[int, List[WGThread]], key: int):
        if self.broadcast:
            self.wake_all()
            return
        lst = waiters.get(key)
        if lst:
            self._drain_waiters(lst)

    def notify_mb(self, cta: CTA, sid: int):
        self._notify_keyed(cta.mb_waiters, sid)

    def notify_stage(self, cta: CTA, sid: int):
        self._notify_keyed(cta.stage_waiters, sid)

    def notify_bar(self, cta: CTA, bid: int):
        self._notify_keyed(cta.bar_waiters, bid)

    def notify_group(self, th: WGThread):
        """One of ``th``'s WGMMA/TMA groups completed work: re-check a
        pending drain wait.  ``parked`` threads wait on something else.
        The drain condition is inlined (it fires once per async completion
        with the waiter usually stalled on exactly this drain)."""
        if self.broadcast:
            self.wake_all()
            return
        if th.state == STALLED and not th.parked:
            ins = th.trace[th.pc]
            op = ins.op
            if op == isa.WGMMA_WAIT:
                pend = th.mma_pending
                if pend and pend[0][0] <= self.engine.cycle:
                    self.tc._apply(th, self.engine.cycle)
                out = th.wgmma_out
            elif op == isa.TMA_WAIT:
                out = th.tma_out
            else:
                return
            if len(out) > ins.n:
                gid = ins.gid
                c = 0
                for g in out:
                    if g <= gid:
                        c += 1
                if c > ins.n:
                    return
            th.state = READY
            if self.event:
                th.in_ready = True
                insort(self._ready, th, key=_ORDER)
            self.engine.mark_active(self)

    def notify_tc(self):
        if not self.broadcast and self.tc.waiters:
            self._drain_waiters(self.tc.waiters)

    # ------------------------------------------------------------------
    def step(self, cycle: int) -> bool:
        """Issue up to issue_width instructions. Returns True if progressed."""
        progressed = False
        broadcast = self.broadcast
        event = self.event
        # hot-loop locals: step runs once per issuing SM per cycle, so every
        # attribute fetch hoisted here is ~50k fewer lookups per launch
        tc = self.tc
        tc_buf = tc.buffer
        tc_cap = self._tc_cap
        tracer = self.tracer
        fast_wgmma = self.san is None
        tc_lazy = tc.lazy
        ready = self._ready
        wgmma = isa.WGMMA
        blocking = _BLOCKING
        for _ in range(self._iw):
            issued = False
            if event:
                # inline of _candidates_event: greedy current thread first,
                # then the maintained ready queue in dispatch order.  Eager
                # snapshot is safe — a candidate's stall processing only
                # removes *itself* from the queue, and an issue breaks out
                # of the scan immediately.  With one ready thread the queue
                # itself is the snapshot (a stall empties it, ending the
                # scan; an issue breaks out before any further iteration).
                cur = self.current
                if len(ready) == 1:
                    cands = ready
                elif cur is not None and cur.in_ready:
                    cands = [cur]
                    for t in ready:
                        if t is not cur:
                            cands.append(t)
                else:
                    cands = list(ready)
            else:
                cands = self._candidates(cycle)
            for th in cands:
                ins = th.trace[th.pc]
                # inline of _cond_met's two hottest outcomes (WGMMA issue
                # and non-blocking ops); the blocking waits take the call
                op = ins.op
                if op == wgmma:
                    if len(tc_buf) < tc_cap:
                        # direct dispatch of the hottest op (skips
                        # _execute's chain; WGMMA has no blocking-side
                        # bookkeeping).  Sanitizer runs keep _execute.
                        nid = (tracer.on_issue(cycle, th, ins)
                               if tracer is not None else -1)
                        if tc_lazy and not tc_buf:
                            # inline of TensorCoreEngine.push's fast path
                            # (same arithmetic, minus the two call frames)
                            pend = th.mma_pending
                            if pend and pend[0][0] <= cycle:
                                tc._apply(th, cycle)
                            gid = ins.gid
                            groups = th.wgmma_groups
                            g = groups.get(gid)
                            if g is None:
                                groups[gid] = g = [0, 0, False]
                            g[0] += 1
                            if g[2] and g[1] == g[0] - 1:
                                th.wgmma_out.add(gid)
                            start = tc.busy_until
                            if start < cycle:
                                start = cycle
                            dur = ins.cycles
                            if dur <= 0:
                                memo = tc._dur_memo
                                dur = memo.get(ins.n)
                                if dur is None:
                                    dur = max(1, int(round(ins.n / tc._div)))
                                    memo[ins.n] = dur
                            fl = tc.faults
                            if fl is not None:
                                dur = fl.stretch(start, self.sm_id, dur)
                            end = start + dur
                            tc.busy_until = end
                            tc.busy_cycles += dur
                            if tracer is not None:
                                tracer.on_mma(nid, th, ins, start, end)
                            th.mma_pending.append((end, gid))
                        elif fast_wgmma:
                            tc.push(cycle, th, ins, nid)
                        else:
                            self._execute(cycle, th, ins, nid)
                        th.pc += 1
                    else:
                        th.state = STALLED
                        if not broadcast:
                            self._park(th, ins)
                        if event and th.in_ready:
                            th.in_ready = False
                            ready.remove(th)
                        if self.current is th:
                            self.current = None
                        continue
                elif op in blocking and not self._cond_met(th, ins):
                    th.state = STALLED   # PC rollback: do not advance
                    if not broadcast:
                        self._park(th, ins)
                    if event and th.in_ready:
                        th.in_ready = False
                        ready.remove(th)
                    if self.current is th:
                        self.current = None
                    continue             # GTO: fall through to next-oldest
                else:
                    # trace before counters mutate: dep ordinals snapshot
                    nid = (tracer.on_issue(cycle, th, ins)
                           if tracer is not None else -1)
                    if op == isa.MB_WAIT:
                        th.mb_expected[ins.sid] = \
                            th.mb_expected.get(ins.sid, 0) + 1
                    elif op == isa.ACQUIRE_STAGE:
                        th.acq_count[ins.sid] = \
                            th.acq_count.get(ins.sid, 0) + 1
                    self._execute(cycle, th, ins, nid)
                    th.pc += 1
                self.current = th        # greedy: keep issuing this thread
                issued = True
                if th.pc >= th.trace_len:
                    th.state = DONE
                    self.current = None
                    if event and th.in_ready:
                        th.in_ready = False
                        self._ready.remove(th)
                    # retirement waits for trailing in-flight work (bubbles)
                    fin = max(cycle, th.busy_until)
                    if fin > cycle:
                        self.evq.push(fin, self._finish_thread, th)
                    else:
                        self._finish_thread(th)
                break
            if not issued:
                break
            progressed = True
        return progressed

    def _candidates(self, cycle: int):
        """Greedy-then-oldest order: current thread first, then dispatch order."""
        cur = self.current
        if (cur is not None and cur.state == READY
                and cur.pc < cur.trace_len and cur.busy_until <= cycle):
            yield cur
        for th in self._threads:
            if th is cur:
                continue
            if (th.state == READY and th.pc < th.trace_len
                    and th.busy_until <= cycle):
                yield th

    def _execute(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        if self.san is not None:
            self.san.on_execute(cycle, th, ins)
        op = ins.op
        cta = th.cta
        if op == isa.WGMMA:             # hottest op: dispatched first
            self.tc.push(cycle, th, ins, nid)
        elif op == isa.TMA_TENSOR:
            self.tma.submit_load(cycle, th, ins, nid)
        elif op == isa.TMA_STORE:
            self.tma.submit_store(cycle, th, ins, nid)
        elif op == isa.WGMMA_COMMIT:
            if th.mma_pending:
                self.tc._apply(th, cycle)
            g = th.wgmma_groups.setdefault(ins.gid, [0, 0, False])
            if not g[2]:
                g[2] = True
                if g[1] < g[0]:
                    th.wgmma_out.add(ins.gid)
        elif op == isa.TMA_COMMIT:
            g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
            if not g[2]:
                g[2] = True
                if g[1] < g[0]:
                    th.tma_out.add(ins.gid)
        elif op == isa.RELEASE_STAGE:
            cta.stage_releases[ins.sid] = cta.stage_releases.get(ins.sid, 0) + 1
            self.notify_stage(cta, ins.sid)
        elif op == isa.BAR_ARRIVE:
            cta.bar_arrivals[ins.bid] = cta.bar_arrivals.get(ins.bid, 0) + 1
            self.notify_bar(cta, ins.bid)
        elif op == isa.BUBBLES:
            fl = self.faults
            until = cycle + (ins.cycles if fl is None
                             else fl.stretch(cycle, self.sm_id, ins.cycles))
            th.busy_until = until
            if self.event:
                # park on a per-SM timer: one coalesced wake per (cycle, SM)
                # instead of one broadcast wake_all per bubble
                if th.in_ready:
                    th.in_ready = False
                    self._ready.remove(th)
                lst = self._timers.get(until)
                if lst is None:
                    self._timers[until] = [th]
                    self.evq.wake_at(until, self._timer_fire)
                else:
                    lst.append(th)
            else:
                self.evq.push(until, self.wake_all)
        # waits that reached here had their condition met: no-op

    def _finish_thread(self, th: WGThread):
        th.cta.done_wgs += 1
        self._rebuild_threads()
        if th.cta.done_wgs == len(th.cta.threads):
            self._retire_cta(th.cta)

    def _retire_cta(self, cta: CTA):
        self.ctas.remove(cta)
        self._rebuild_threads()
        self.engine.cta_retired(self, cta)

    def all_blocked(self, cycle: int) -> bool:
        for th in self._threads:
            if (th.state == READY and th.pc < th.trace_len
                    and th.busy_until <= cycle):
                return False
        return True

    def unstall(self):
        """Re-mark stalled threads READY so conditions get re-checked.
        Broadcast-mode fallback only — waiter mode wakes via the index."""
        for th in self._threads:
            if th.state == STALLED:
                th.state = READY


class Engine:
    """Top level: CTA dispatcher + global cycle loop (Algorithm 1)."""

    SCHEDULERS = ("event", "waiter", "broadcast")
    MEM_FIDELITIES = ("line", "tile")

    def __init__(self, machine: GPUMachine, n_sms: Optional[int] = None,
                 mem_scale: Optional[float] = None, record_gantt: bool = False,
                 seed: int = 0, direct_hbm: bool = False, tracer=None,
                 broadcast_wake: bool = False,
                 scheduler: Optional[str] = None,
                 counters=None, sanitize: bool = False,
                 faults=None, watchdog=None,
                 mem_fidelity: str = "line"):
        if scheduler is None:
            scheduler = "broadcast" if broadcast_wake else "event"
        elif scheduler not in self.SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; "
                             f"expected one of {self.SCHEDULERS}")
        elif broadcast_wake and scheduler != "broadcast":
            raise ValueError("broadcast_wake=True conflicts with "
                             f"scheduler={scheduler!r}")
        if mem_fidelity not in self.MEM_FIDELITIES:
            raise ValueError(f"unknown mem_fidelity {mem_fidelity!r}; "
                             f"expected one of {self.MEM_FIDELITIES}")
        self.scheduler = scheduler
        self.mem_fidelity = mem_fidelity
        self.cfg = machine
        self.n_sms = n_sms or machine.num_sms
        scale = mem_scale if mem_scale is not None else self.n_sms / machine.num_sms
        self.evq = EventQueue()
        self.lrc, self.l2, self.dram = build_memory(machine, self.evq, scale,
                                                    seed, direct=direct_hbm,
                                                    tile=mem_fidelity == "tile")
        self.tmaps: Dict[int, TensorMap] = {}
        self.tile_cache: Dict[tuple, list] = {}   # (map_id, origin) -> lines
        self.tile_seen: set = set()               # keys seen exactly once
        if tracer is None and record_gantt:
            # gantt is now a view over the structured event trace
            from repro.analysis.events import EventTracer
            tracer = EventTracer()
        self.tracer = tracer
        self.record_gantt = tracer is not None
        # opt-in PM-counter sink (obs.counters.CounterSink).  The run loops
        # only ever *read* engine state through it at window boundaries, so
        # attaching one cannot change simulated behavior (bit-neutrality is
        # enforced in tests/test_engine_equiv.py); when None the cost is a
        # single is-None test per loop iteration.
        self.counters = counters
        # opt-in runtime hazard sanitizer (analysis.hazards): TSan-style
        # per-event cross-check of the ring protocol, read-only over
        # simulated state like the counter sink, so bit-neutral by the
        # same argument; when off the cost is one is-None test per issue
        self.sanitizer = None
        if sanitize:
            from repro.analysis.hazards import HazardSanitizer
            self.sanitizer = HazardSanitizer()
        # populated by analysis.hazards.explain_deadlock the moment a run
        # loop concludes nothing can ever progress again (deadlocked=True);
        # deliberately NOT part of stats() — diagnostics, not simulation
        self.deadlock_info: Optional[dict] = None
        # opt-in seeded fault/variability session (repro.faults): latency
        # jitter, SM slowdown/offlining, throttle windows, delayed async
        # completions.  Same hook discipline as the counter sink: every
        # site costs one is-None test when off, and an identity plan draws
        # +0 extra cycles everywhere, so attaching it is bit-exact.  The
        # session's RNG is private — the engine RNG stream is untouched.
        self.faults = None
        if faults is not None:
            from repro.faults.session import make_session
            self.faults = make_session(faults, self.n_sms)
            fl = self.faults
            self.dram.faults = fl
            self.lrc.faults = fl
            if self.l2 is not self.lrc:     # sliced L2 (not DirectHBM)
                self.l2.faults = fl
                for sl in self.l2.slices:
                    sl.faults = fl
        # opt-in run watchdog (repro.faults.watchdog): wall-clock /
        # sim-cycle budgets with clean abort + partial-result salvage.
        # Read-only over simulated state; a run that finishes under budget
        # is bit-exact with an unwatched run.
        self.watchdog = None
        if watchdog is not None:
            from repro.faults.watchdog import make_watchdog
            self.watchdog = make_watchdog(watchdog)
        self.aborted = False
        self.abort_info: Optional[dict] = None
        self.broadcast_wake = scheduler == "broadcast"
        self.sms = [SM(i, machine, self) for i in range(self.n_sms)]
        self.pending: deque = deque()
        self.cycle = 0
        self.launched = 0
        self.retired = 0
        self.deadlocked = False
        self._active = set(range(self.n_sms))
        # event mode: the active set is a maintained ordered structure —
        # sorted list of active sm ids plus a membership flag per SM: the
        # run loop sweeps a tuple snapshot in ascending-id order, and in
        # steady state (every swept SM still issue-eligible) pays zero
        # maintenance — wakes insort (rare), removals trigger one rebuild
        self._active_list: List[int] = list(range(self.n_sms))
        self._active_flags = bytearray([1]) * self.n_sms

    # ------------------------------------------------------------------
    def define_tmap(self, tm: TensorMap):
        self.tmaps[tm.map_id] = tm

    def launch(self, ctas: List[CTATrace]):
        self.pending.extend(ctas)
        self._dispatch()

    def _dispatch(self, parent: Optional[int] = None):
        fl = self.faults
        off = fl.offline if fl is not None and fl.offline else None
        for sm in self.sms:
            if off is not None and sm.sm_id in off:
                continue                 # fenced/dead SM: no CTAs dispatched
            added = False
            while self.pending and sm.has_slot():
                trace = self.pending.popleft()
                cta = CTA(trace, self.launched)
                self.launched += 1
                sm.ctas.append(cta)
                for th in cta.threads:
                    th.sm = sm
                    if sm.event:
                        th.in_ready = True
                        insort(sm._ready, th, key=_ORDER)
                added = True
                if self.tracer is not None:
                    self.tracer.on_dispatch(cta.idx, parent)
                self.mark_active(sm)
            if added:
                sm._rebuild_threads()

    def cta_retired(self, sm: SM, cta: CTA):
        self.retired += 1
        if self.sanitizer is not None:
            self.sanitizer.on_cta_retired(self.cycle, cta)
        self._dispatch(parent=cta.idx)

    def mark_active(self, sm: SM):
        if sm.event:
            sid = sm.sm_id
            if not self._active_flags[sid]:
                self._active_flags[sid] = 1
                insort(self._active_list, sid)
            return
        self._active.add(sm.sm_id)
        if self.broadcast_wake:
            sm.unstall()

    # ------------------------------------------------------------------
    def run(self, max_cycles: int = 2_000_000_000) -> dict:
        if self.scheduler == "event":
            return self._run_event(max_cycles)
        broadcast = self.broadcast_wake
        active = self._active
        sms = self.sms
        evq = self.evq
        snk = self.counters
        wd = self.watchdog
        while self.cycle < max_cycles:
            evq.pop_ready(self.cycle)
            if snk is not None and self.cycle >= snk.next_sample:
                snk.sample(self.cycle, self)
            if self.retired == self.launched and not self.pending:
                break
            if wd is not None and wd.tripped(self.cycle):
                self._abort(wd)
                break
            progressed = False
            if active:
                # ascending sm id == the insertion-ordered small-int set
                # iteration the broadcast engine always produced
                for sid in sorted(active):
                    sm = sms[sid]
                    if sm.step(self.cycle):
                        progressed = True
                        sm.issue_cycles += 1
                    elif sm.all_blocked(self.cycle):
                        active.discard(sid)
            if progressed:
                self.cycle += 1
                continue
            nxt = evq.next_cycle()
            if nxt is None:
                # threads may be waiting on busy_until (bubbles) -- find min
                wake = [th.busy_until for sm in sms for th in sm.threads()
                        if th.state == READY and not th.done()
                        and th.busy_until > self.cycle]
                if not wake:
                    self._flag_deadlock()
                    break
                self.cycle = min(wake) if wd is None else wd.clamp(min(wake))
                for sm in sms:
                    self.mark_active(sm)
            else:
                nxt = max(self.cycle + 1, nxt)
                self.cycle = nxt if wd is None else wd.clamp(nxt)
                if broadcast:
                    # legacy rescan: re-mark every SM after each time jump
                    for sm in sms:
                        self.mark_active(sm)
        if snk is not None:
            snk.finish(self.cycle, self)
        return self.stats()

    def _run_event(self, max_cycles: int) -> dict:
        """Discrete-event run loop (default scheduler).

        Time advances straight to the next interesting cycle: the event-queue
        head (memory completions, busy-timer wakes, thread retirements) or
        the next cycle any SM can issue.  Nothing here scans threads:
        ``sm._ready`` is the maintained per-SM issue-eligible queue, the
        active set is a flag-guarded min-heap of SM ids drained in ascending
        order, and busy_until sleepers wake via coalesced per-SM timers —
        there is no broadcast wake and no O(threads) busy-scan fallback.

        The snapshot discipline matches the legacy loop exactly: the set of
        SMs stepped in a cycle is fixed before any of them steps, so an SM
        woken mid-sweep first issues on the following cycle."""
        sms = self.sms
        evq = self.evq
        evh = evq._h     # heap head probed inline: most cycles drain nothing
        lst = self._active_list
        flags = self._active_flags
        snk = self.counters
        wd = self.watchdog
        while self.cycle < max_cycles:
            if evh and evh[0] <= self.cycle:
                evq.pop_ready(self.cycle)
            if snk is not None and self.cycle >= snk.next_sample:
                snk.sample(self.cycle, self)
            if self.retired == self.launched and not self.pending:
                break
            if wd is not None and wd.tripped(self.cycle):
                self._abort(wd)
                break
            progressed = False
            if lst:
                # snapshot discipline: only SMs active at cycle start are
                # swept (ascending sm id); mid-sweep wakes insort into lst
                # and issue next cycle.  A removal transiently leaves its
                # stale entry in lst (flag 0), so a re-wake within the same
                # sweep can duplicate it — the rebuild below dedups.
                snapshot = tuple(lst)
                removed = False
                for sid in snapshot:
                    sm = sms[sid]
                    if sm._ready:
                        if sm.step(self.cycle):
                            progressed = True
                            sm.issue_cycles += 1
                        if not sm._ready:
                            flags[sid] = 0
                            removed = True
                    else:
                        flags[sid] = 0
                        removed = True
                if removed:
                    seen = set()
                    keep = []
                    for sid in lst:
                        if flags[sid] and sid not in seen:
                            seen.add(sid)
                            keep.append(sid)
                    lst[:] = keep
            if progressed:
                self.cycle += 1
                continue
            nxt = evq.next_cycle()
            if nxt is None:
                # no issuable thread, no pending event: nothing can ever
                # make progress again (busy sleepers hold queue timers)
                self._flag_deadlock()
                break
            nxt = max(self.cycle + 1, nxt)
            self.cycle = nxt if wd is None else wd.clamp(nxt)
        if snk is not None:
            snk.finish(self.cycle, self)
        return self.stats()

    # ------------------------------------------------------------------
    def _abort(self, wd):
        """Watchdog trip: break the run loop cleanly and salvage a partial
        result (CTA census, blocked-thread snapshot, fault stats) instead
        of hanging or dying.  Counters still get their finish() sample —
        the loops run it after the break — so PM timelines up to the abort
        survive too."""
        from repro.faults.watchdog import salvage
        self.aborted = True
        self.abort_info = salvage(self, wd.reason, wd.wall_s())

    def _flag_deadlock(self):
        """Both run loops land here when nothing can ever progress again.
        Attaches the wait-for-graph explanation (which thread blocks on
        which sid/bid, witness cycle) instead of just flipping the bool;
        runs after the loop already decided to break, so it cannot perturb
        simulated state."""
        self.deadlocked = self.retired < self.launched
        if self.deadlocked:
            from repro.analysis.hazards import explain_deadlock
            self.deadlock_info = explain_deadlock(self)

    def stats(self) -> dict:
        l2 = self.l2.stats()
        tc_busy = sum(sm.tc.busy_cycles for sm in self.sms)
        return {
            "cycles": self.cycle,
            "time_us": self.cycle / (self.cfg.freq_ghz * 1e3),
            "ctas": self.retired,
            "l2": l2,
            "l2_req_bytes": l2["requests"] * self.cfg.line_bytes,
            "dram_bytes": self.dram.bytes_served,
            "lrc_merged": self.lrc.merged,
            "tma_lines": sum(sm.tma.lines_issued for sm in self.sms),
            "tc_busy_cycles": tc_busy,
            "tc_util": tc_busy / max(1, self.cycle * self.n_sms),
        }

    def gantt(self) -> List[Tuple[str, int, int]]:
        """Legacy flat-interval view, derived from the structured trace."""
        if self.tracer is None:
            return []
        from repro.core.gantt import from_events
        return from_events(self.tracer.events)
