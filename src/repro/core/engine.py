"""Sim-FA core: event-driven, WarpGroup-granular cycle-level engine.

Implements the paper's Algorithm 1:
  * each WarpGroup is a *logical thread* with a single instruction flow;
  * the Scheduler dispatches logical threads (grouped in CTAs) to physical
    SM slots under the occupancy limit, and plays warp-scheduler (GTO)
    among resident threads;
  * the Frontend issues in order, executes out of order: async ops are
    handed to the TMA / TensorCore engines, waits with unmet conditions
    roll the PC back and park the thread on a waiter list (AEQ);
  * mbarriers, pipeline stages (producer_acquire / consumer_release),
    WGMMA commit groups, TMA store groups and named barriers are modeled
    in full — the paper found incomplete barrier modeling breaks overlap
    estimation (§4.1).

The default run loop (``scheduler="event"``) is a true discrete-event
loop: time jumps straight to the next interesting cycle — the event-queue
head or the next cycle any SM can issue — and *nothing scans threads*.
Each SM keeps a maintained issue-eligible ready queue (READY, non-busy,
non-done threads in GTO dispatch order), ``busy_until`` sleepers park on
coalesced per-SM timer events (``EventQueue.wake_at``), and the active-SM
set is a flag-guarded min-heap drained in ascending id order.  That is
what makes a Python implementation viable where the paper uses C++.

Scheduling is *condition-indexed*: a thread whose wait condition fails is
parked on a waiter list keyed by exactly what it waits for — an mbarrier
``(cta, sid)`` signal, a stage-release count, its own WGMMA/TMA group
drain, a named-barrier arrival, a tensor-core buffer slot, or a
``busy_until`` timer — and each completion event wakes only the threads
whose condition just became satisfiable.  A woken thread's condition is
always re-validated at issue time in ``SM.step``, so a spurious wake is
harmless; the wake index only has to never *miss* a wake.  Two fallback
schedulers survive for equivalence testing and deadlock safety:
``scheduler="waiter"`` (the condition-indexed scan loop this PR's event
loop grew out of) and ``scheduler="broadcast"`` / ``broadcast_wake=True``
(every completion re-marks every resident thread READY and rescans).  All
three are cycle-for-cycle *bit-exact* — identical ``stats()`` dicts and
event streams (see ``tests/test_engine_equiv.py``).
"""
from __future__ import annotations

from bisect import insort
from collections import deque
from dataclasses import dataclass
from heapq import heappop, heappush
from operator import attrgetter
from typing import Dict, List, Optional, Tuple

from repro.core import isa
from repro.core.isa import Instr, TensorMap
from repro.core.machine import GPUMachine
from repro.core.memory import EventQueue, build_memory
from repro.obs.labels import make_label

READY, STALLED, DONE = 0, 1, 2

_ORDER = attrgetter("order")    # GTO dispatch-order sort key


@dataclass
class CTATrace:
    """One thread block: a list of WarpGroup instruction traces.

    ``roles`` optionally names each warpgroup's declared role instance
    (e.g. ``["producer", "consumer0", "consumer1"]``, from the kernel IR);
    thread labels — and therefore stall-attribution keys — use these names
    instead of positional ``wg{i}`` indices when present.

    ``rings`` optionally maps each declared ring buffer to its stage sids
    (``{"K": (0, 2), "V": (1, 3)}``, from the kernel IR) — pure metadata
    the engine never reads; the counter sink uses it to derive per-ring
    occupancy depth from the mbarrier/release state.

    ``tokens`` (name -> sid) and ``acq_slots`` (per-WG ``{instr index:
    (ring, raw slot)}`` for ACQUIRE_STAGE instructions) are further
    IR-metadata riders consumed by the static verifier
    (``repro.core.kprog.verify``) — sid-space collision checks need the
    token allocation, and slot-aliasing witnesses need the pre-wrap slot
    numbers that lowering folds into sids."""
    wgs: List[List[Instr]]
    n_consumers: int = 2
    name: str = ""
    roles: Optional[List[str]] = None
    rings: Optional[Dict[str, Tuple[int, ...]]] = None
    tokens: Optional[Dict[str, int]] = None
    acq_slots: Optional[List[Dict[int, Tuple[str, int]]]] = None


class WGThread:
    __slots__ = ("trace", "trace_len", "pc", "state", "cta", "wg_id", "sm",
                 "busy_until", "wgmma_groups", "tma_groups", "wgmma_out",
                 "tma_out", "mb_expected", "acq_count", "label", "parked",
                 "order", "in_ready")

    def __init__(self, trace, cta, wg_id):
        self.trace = trace
        self.trace_len = len(trace)
        self.pc = 0
        self.state = READY
        self.cta = cta
        self.wg_id = wg_id
        self.sm = None
        self.busy_until = 0
        # per-WG async group bookkeeping: gid -> [issued, completed, committed]
        self.wgmma_groups: Dict[int, List] = {}
        self.tma_groups: Dict[int, List] = {}
        # committed-but-incomplete group ids; len() is the outstanding count
        # the drain waits test, so WGMMA_WAIT/TMA_WAIT checks are O(1)
        self.wgmma_out: set = set()
        self.tma_out: set = set()
        self.mb_expected: Dict[int, int] = {}
        self.acq_count: Dict[int, int] = {}
        self.label = ""
        self.parked = False      # registered on a keyed waiter list
        self.order = (0, wg_id)  # GTO dispatch-order key, set by CTA
        self.in_ready = False    # member of its SM's issue-eligible queue

    def done(self):
        return self.pc >= self.trace_len


class CTA:
    __slots__ = ("trace", "threads", "mbarrier", "stage_releases",
                 "bar_arrivals", "n_consumers", "idx", "done_wgs",
                 "mb_waiters", "stage_waiters", "bar_waiters")

    def __init__(self, trace: CTATrace, idx: int):
        self.trace = trace
        self.idx = idx
        self.n_consumers = trace.n_consumers
        self.threads = [WGThread(t, self, i) for i, t in enumerate(trace.wgs)]
        roles = trace.roles
        for i, t in enumerate(self.threads):
            role = roles[i] if roles and i < len(roles) else f"wg{i}"
            t.label = make_label(idx, role)
            t.order = (idx, i)
        self.mbarrier: Dict[int, int] = {}        # sid -> completed signals
        self.stage_releases: Dict[int, int] = {}  # sid -> consumer releases
        self.bar_arrivals: Dict[int, int] = {}    # bid -> arrivals
        self.done_wgs = 0
        # condition-indexed waiter lists (waiter-mode scheduler only)
        self.mb_waiters: Dict[int, List[WGThread]] = {}
        self.stage_waiters: Dict[int, List[WGThread]] = {}
        self.bar_waiters: Dict[int, List[WGThread]] = {}


class TensorCoreEngine:
    """Single tensor-core pipeline + WGMMA issue buffer per SM (§4.2)."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.buffer: deque = deque()   # (WGThread, Instr, nid)
        # Defensive waiter list: _pump pops synchronously on every push
        # (serialization is modeled via busy_until), so with the current
        # pipeline model can_accept() never fails and nothing parks here.
        # The list exists so a future occupancy-accurate buffer model can't
        # introduce a missed-wake deadlock on the WGMMA stall path.
        self.waiters: List[WGThread] = []   # threads parked on a buffer slot
        self.busy_until = 0
        self.busy_cycles = 0
        self.faults = sm.engine.faults

    def can_accept(self) -> bool:
        return len(self.buffer) < self.cfg.wgmma_issue_buffer

    def push(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        g = th.wgmma_groups.setdefault(ins.gid, [0, 0, False])
        g[0] += 1
        if g[2] and g[1] == g[0] - 1:
            # a committed, fully drained group id got reused: outstanding again
            th.wgmma_out.add(ins.gid)
        self.buffer.append((th, ins, nid))
        self._pump(cycle)

    def _pump(self, cycle: int):
        if not self.buffer:
            return
        start = max(cycle, self.busy_until)
        th, ins, nid = self.buffer.popleft()
        # GPU mode: FP16 m64nNk16 completes in ~N/2 cycles (paper §4.2);
        # TPU mode: the tracegen precomputes MXU cycles into ins.cycles.
        dur = ins.cycles if ins.cycles > 0 else max(
            1, int(round(ins.n / self.cfg.wgmma_n_cycles_divisor)))
        fl = self.faults
        if fl is not None:
            dur = fl.stretch(start, self.sm.sm_id, dur)
        self.busy_until = start + dur
        self.busy_cycles += dur
        if self.sm.tracer is not None:
            self.sm.tracer.on_mma(nid, th, ins, start, start + dur)
        self.evq.push(start + dur, self._complete, th, ins.gid)

    def _complete(self, th: WGThread, gid: int):
        g = th.wgmma_groups[gid]
        g[1] += 1
        if g[2] and g[1] >= g[0]:
            th.wgmma_out.discard(gid)
        self.sm.notify_group(th)
        self._pump(self.busy_until)
        self.sm.notify_tc()


class TMAEngine:
    """Per-SM TMA engine: descriptor setup, HW address generation with line
    dedup, bounded in-flight lines, mbarrier signaling (§4.3).

    The line path is *batched*: each cycle's issuable lines go to the LRC in
    one ``request_many`` call sharing a single per-job completion callback
    (a shared counter), instead of one closure per line; finished jobs are
    retired at completion time, so ``jobs`` only ever holds live jobs."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm, lrc, tmaps):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.eng = sm.engine
        self.lrc = lrc
        self.tmaps = tmaps
        self.faults = sm.engine.faults
        # frozen-config hot constants, hoisted off the issue path
        self._lpc = cfg.tma_lines_per_cycle
        self._cap = cfg.tma_max_inflight_lines
        self.jobs: List[dict] = []    # live jobs, round-robin issue order
        self.lines_issued = 0
        self.lines_queued = 0         # un-issued lines across all live jobs
        self._kick_scheduled = False
        self._issue_cycle = -1
        self._issued_in_cycle = 0

    def _tile_lines(self, ins: Instr):
        """Hardware address generation, cached per (map, origin): CTAs of the
        same KV head stream identical K/V tiles (Eq. 5/6 reuse structure).
        Caching starts on the *second* encounter so per-CTA-unique tiles
        (Q loads, O stores) cost a set entry, not a retained line list."""
        eng = self.sm.engine
        key = (ins.map_id, ins.origin)
        lines = eng.tile_cache.get(key)
        if lines is None:
            tm: TensorMap = self.tmaps[ins.map_id]
            lines = tm.tile_lines(ins.origin, self.cfg.line_bytes,
                                  dedup=self.cfg.tma_dedup)
            seen = eng.tile_seen
            if key in seen:
                eng.tile_cache[key] = lines
            else:
                seen.add(key)
        return lines

    def submit_load(self, cycle: int, th: WGThread, ins: Instr,
                    nid: int = -1):
        lines = self._tile_lines(ins)
        # Fig. 2: non-tensor bulk requests bypass the descriptor cache and
        # TensorMap setup path -> only the common launch latency applies.
        setup = self.cfg.tma_launch_latency + (
            0 if ins.bulk else self.cfg.tma_tmap_setup_latency)
        fl = self.faults
        if fl is not None:
            setup += fl.tma_extra()
        job = {"lines": deque(lines), "left": len(lines), "th": th,
               "sid": ins.sid, "write": False, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        job["done"] = self._make_done(job)
        self.evq.push(cycle + setup, self._start, job)

    def submit_store(self, cycle: int, th: WGThread, ins: Instr,
                     nid: int = -1):
        lines = self._tile_lines(ins)
        g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
        g[0] += 1
        if g[2] and g[1] == g[0] - 1:
            th.tma_out.add(ins.gid)
        # stores bypass the TensorMap setup path only when bulk (Fig. 2);
        # FA3's O store uses a TensorMap -> full setup
        setup = self.cfg.tma_launch_latency + self.cfg.tma_tmap_setup_latency
        fl = self.faults
        if fl is not None:
            setup += fl.tma_extra()
        job = {"lines": deque(lines), "left": len(lines), "th": th,
               "gid": ins.gid, "write": True, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        job["done"] = self._make_done(job)
        self.evq.push(cycle + setup, self._start, job)

    def _make_done(self, job):
        """One shared completion callback per job — the LRC invokes it once
        per finished line (shared counter, no per-line closures).

        The steady-state path is *targeted*: between issue events, only the
        job whose line just completed can have both queued lines and
        in-flight room (any other job with room would already have issued
        when capacity last appeared), so — mid-cycle, budget permitting —
        its replacement line is issued directly instead of re-scanning all
        live jobs.  The full scan is kept for cycle boundaries, where the
        budget resets and rate-limited jobs must issue in legacy order."""
        lrc = self.lrc
        eng = self.eng
        sm_id = self.sm.sm_id
        def done():
            job["left"] -= 1
            if job["left"] == 0:
                fl = eng.faults
                d = fl.finish_delay() if fl is not None else 0
                if d:
                    # delayed async-completion delivery: the last line has
                    # landed but the mbarrier signal / group retirement only
                    # becomes visible d cycles later.  The job stays in
                    # self.jobs (empty line deque -> _issue skips it).
                    self.evq.push(eng.cycle + d, self._finish, job)
                else:
                    self._finish(job)
                if (self.lines_queued and self._kick_scheduled
                        and eng.cycle > self._issue_cycle):
                    self._issue(eng.cycle)
                return
            lines = job["lines"]
            now = eng.cycle
            if now > self._issue_cycle:
                if self._kick_scheduled:
                    # an unfired carry-over kick covers rate-limited jobs
                    # that must issue first, in legacy scan order
                    job["inflight"] -= 1
                    if self.lines_queued:
                        self._issue(now)
                    return
                if not lines:
                    job["inflight"] -= 1
                    return
                # fresh cycle, no carry-over work: the budget resets and
                # this job is the only issue-eligible one
                self._issue_cycle = now
                self._issued_in_cycle = 1
                self.lines_issued += 1
                self.lines_queued -= 1
                lrc.request_one(now, lines.popleft(), sm_id, done,
                                job["write"])
                return
            if lines and self._issued_in_cycle < self._lpc:
                # targeted refill: this job freed exactly one slot, and no
                # other job can be issue-eligible mid-cycle (see above)
                self._issued_in_cycle += 1
                self.lines_issued += 1
                self.lines_queued -= 1
                lrc.request_one(now, lines.popleft(), sm_id, done,
                                job["write"])
                return
            job["inflight"] -= 1
            if lines and not self._kick_scheduled:
                # budget spent with lines still queued: carry over
                self._kick_scheduled = True
                self.evq.push(now + 1, self._kick)
        return done

    def _start(self, job):
        self.jobs.append(job)
        self.lines_queued += len(job["lines"])
        self._issue(self._now())

    def _now(self):
        return self.eng.cycle

    def _issue(self, cycle: int):
        """Issue up to tma_lines_per_cycle lines this cycle, round-robin over
        in-flight TMA ops; max_inflight_lines bounds each op's outstanding
        lines (several ops stream concurrently through the ring buffer)."""
        if cycle > self._issue_cycle:
            self._issue_cycle = cycle
            self._issued_in_cycle = 0
        budget = self._lpc - self._issued_in_cycle
        if budget > 0 and self.lines_queued:
            inflight_cap = self._cap
            request_one = self.lrc.request_one
            sm_id = self.sm.sm_id
            for job in self.jobs:
                if budget <= 0:
                    break
                lines = job["lines"]
                take = budget
                n = len(lines)
                if n < take:
                    take = n
                room = inflight_cap - job["inflight"]
                if room < take:
                    take = room
                if take <= 0:
                    continue
                job["inflight"] += take
                self.lines_issued += take
                self.lines_queued -= take
                self._issued_in_cycle += take
                budget -= take
                done_cb = job["done"]
                write = job["write"]
                for _ in range(take):
                    request_one(cycle, lines.popleft(), sm_id, done_cb,
                                write)
        # rate-limited this cycle with lines still issuable: kick next cycle.
        # (inflight-capped jobs are re-kicked by their done() callbacks)
        if (self.lines_queued and not self._kick_scheduled
                and self._issued_in_cycle >= self._lpc):
            cap = self._cap
            for j in self.jobs:
                if j["lines"] and j["inflight"] < cap:
                    self._kick_scheduled = True
                    self.evq.push(cycle + 1, self._kick)
                    break

    def _kick(self):
        self._kick_scheduled = False
        self._issue(self._now())

    def _finish(self, job):
        th: WGThread = job["th"]
        signal_n = 0
        if job["write"]:
            g = th.tma_groups[job["gid"]]
            g[1] += 1
            if g[2] and g[1] >= g[0]:
                th.tma_out.discard(job["gid"])
        else:
            cta = th.cta
            cta.mbarrier[job["sid"]] = cta.mbarrier.get(job["sid"], 0) + 1
            signal_n = cta.mbarrier[job["sid"]]
        if self.sm.tracer is not None:
            self.sm.tracer.on_tma(
                job["nid"], th, write=job["write"], tag=job["tag"],
                t0=job["t0"], t1=self._now(), fixed=job["setup"],
                sid=job.get("sid", -1), gid=job.get("gid", -1),
                signal_n=signal_n)
        self.jobs.remove(job)
        if job["write"]:
            self.sm.notify_group(th)
        else:
            self.sm.notify_mb(th.cta, job["sid"])


class SM:
    def __init__(self, sm_id: int, cfg: GPUMachine, engine):
        self.sm_id = sm_id
        self.cfg = cfg
        self.engine = engine
        self.evq = engine.evq
        self.tracer = engine.tracer
        self.broadcast = engine.broadcast_wake
        self.event = engine.scheduler == "event"
        self.san = engine.sanitizer
        self.faults = engine.faults
        self.ctas: List[CTA] = []
        self._threads: List[WGThread] = []   # flat resident non-DONE threads
        # event-mode issue-eligible queue: READY, non-busy, non-done threads
        # in GTO dispatch order (sorted by WGThread.order); kept exact by the
        # state transitions in step()/_execute()/wakes, so neither step() nor
        # the run loop ever scans blocked threads
        self._ready: List[WGThread] = []
        # event-mode busy-timer park: wake cycle -> threads sleeping on
        # busy_until (BUBBLES), woken by one coalesced evq.wake_at per cycle
        self._timers: Dict[int, List[WGThread]] = {}
        self.tc = TensorCoreEngine(cfg, self.evq, self)
        self.tma = TMAEngine(cfg, self.evq, self, engine.lrc, engine.tmaps)
        self.current: Optional[WGThread] = None   # GTO greedy pointer
        self.issue_cycles = 0

    # ------------------------------------------------------------------
    def threads(self):
        return self._threads

    def _rebuild_threads(self):
        self._threads = [th for cta in self.ctas for th in cta.threads
                         if th.state != DONE]

    def wake_all(self):
        self.engine.mark_active(self)

    def _timer_fire(self, cycle: int):
        """Coalesced busy_until timer (event mode): return every thread whose
        bubble drains at ``cycle`` to the ready queue.  Threads that went
        DONE while draining (trace ended on the bubble) are skipped — their
        retirement is a separate _finish_thread event."""
        for th in self._timers.pop(cycle, ()):
            if th.state == READY:
                th.in_ready = True
                insort(self._ready, th, key=_ORDER)
        if self._ready:
            self.engine.mark_active(self)

    def has_slot(self) -> bool:
        return len(self.ctas) < self.cfg.occupancy_limit

    # ------------------------------------------------------------------
    # condition checks for blocking instructions
    def _cond_met(self, th: WGThread, ins: Instr) -> bool:
        cta = th.cta
        op = ins.op
        if op == isa.MB_WAIT:
            need = th.mb_expected.get(ins.sid, 0) + 1
            return cta.mbarrier.get(ins.sid, 0) >= need
        if op == isa.ACQUIRE_STAGE:
            use = th.acq_count.get(ins.sid, 0)
            if use == 0:
                return True
            return cta.stage_releases.get(ins.sid, 0) >= use * cta.n_consumers
        if op == isa.WGMMA_WAIT:
            out = th.wgmma_out
            if len(out) <= ins.n:       # O(1) fast path: total outstanding
                return True
            gid = ins.gid
            return sum(1 for g in out if g <= gid) <= ins.n
        if op == isa.TMA_WAIT:
            out = th.tma_out
            if len(out) <= ins.n:
                return True
            gid = ins.gid
            return sum(1 for g in out if g <= gid) <= ins.n
        if op == isa.BAR_WAIT:
            return cta.bar_arrivals.get(ins.bid, 0) >= ins.n
        if op == isa.WGMMA:
            return self.tc.can_accept()
        return True

    def _apply_blocking(self, th: WGThread, ins: Instr):
        if ins.op == isa.MB_WAIT:
            th.mb_expected[ins.sid] = th.mb_expected.get(ins.sid, 0) + 1
        elif ins.op == isa.ACQUIRE_STAGE:
            th.acq_count[ins.sid] = th.acq_count.get(ins.sid, 0) + 1

    # ------------------------------------------------------------------
    # waiter index: park / targeted wake (waiter-mode scheduler)
    def _park(self, th: WGThread, ins: Instr):
        """Register a freshly stalled thread under its wake condition.
        WGMMA_WAIT/TMA_WAIT drain only on this thread's own group
        completions, so those are probed directly (no list needed)."""
        if th.parked:
            return
        op = ins.op
        if op == isa.MB_WAIT:
            th.cta.mb_waiters.setdefault(ins.sid, []).append(th)
        elif op == isa.ACQUIRE_STAGE:
            th.cta.stage_waiters.setdefault(ins.sid, []).append(th)
        elif op == isa.BAR_WAIT:
            th.cta.bar_waiters.setdefault(ins.bid, []).append(th)
        elif op == isa.WGMMA:
            self.tc.waiters.append(th)
        else:                       # WGMMA_WAIT / TMA_WAIT: probed via
            return                  # notify_group, not list-parked
        th.parked = True

    def _drain_waiters(self, lst: List[WGThread]):
        """Wake every parked thread whose condition now holds."""
        woke = False
        kept = []
        event = self.event
        for th in lst:
            if self._cond_met(th, th.trace[th.pc]):
                th.parked = False
                th.state = READY
                if event:
                    th.in_ready = True
                    insort(self._ready, th, key=_ORDER)
                woke = True
            else:
                kept.append(th)
        lst[:] = kept
        if woke:
            self.engine.mark_active(self)

    def _notify_keyed(self, waiters: Dict[int, List[WGThread]], key: int):
        if self.broadcast:
            self.wake_all()
            return
        lst = waiters.get(key)
        if lst:
            self._drain_waiters(lst)

    def notify_mb(self, cta: CTA, sid: int):
        self._notify_keyed(cta.mb_waiters, sid)

    def notify_stage(self, cta: CTA, sid: int):
        self._notify_keyed(cta.stage_waiters, sid)

    def notify_bar(self, cta: CTA, bid: int):
        self._notify_keyed(cta.bar_waiters, bid)

    def notify_group(self, th: WGThread):
        """One of ``th``'s WGMMA/TMA groups completed work: re-check a
        pending drain wait.  ``parked`` threads wait on something else."""
        if self.broadcast:
            self.wake_all()
            return
        if th.state == STALLED and not th.parked:
            ins = th.trace[th.pc]
            if (ins.op == isa.WGMMA_WAIT or ins.op == isa.TMA_WAIT) \
                    and self._cond_met(th, ins):
                th.state = READY
                if self.event:
                    th.in_ready = True
                    insort(self._ready, th, key=_ORDER)
                self.engine.mark_active(self)

    def notify_tc(self):
        if not self.broadcast and self.tc.waiters:
            self._drain_waiters(self.tc.waiters)

    # ------------------------------------------------------------------
    def step(self, cycle: int) -> bool:
        """Issue up to issue_width instructions. Returns True if progressed."""
        progressed = False
        broadcast = self.broadcast
        event = self.event
        for _ in range(self.cfg.issue_width):
            issued = False
            cands = (self._candidates_event() if event
                     else self._candidates(cycle))
            for th in cands:
                ins = th.trace[th.pc]
                if not self._cond_met(th, ins):
                    th.state = STALLED   # PC rollback: do not advance
                    if not broadcast:
                        self._park(th, ins)
                    if event and th.in_ready:
                        th.in_ready = False
                        self._ready.remove(th)
                    if self.current is th:
                        self.current = None
                    continue             # GTO: fall through to next-oldest
                # trace before counters mutate: dep ordinals snapshot here
                nid = (self.tracer.on_issue(cycle, th, ins)
                       if self.tracer is not None else -1)
                self._apply_blocking(th, ins)
                self._execute(cycle, th, ins, nid)
                th.pc += 1
                self.current = th        # greedy: keep issuing this thread
                issued = True
                if th.pc >= th.trace_len:
                    th.state = DONE
                    self.current = None
                    if event and th.in_ready:
                        th.in_ready = False
                        self._ready.remove(th)
                    # retirement waits for trailing in-flight work (bubbles)
                    fin = max(cycle, th.busy_until)
                    if fin > cycle:
                        self.evq.push(fin, self._finish_thread, th)
                    else:
                        self._finish_thread(th)
                break
            if not issued:
                break
            progressed = True
        return progressed

    def _candidates(self, cycle: int):
        """Greedy-then-oldest order: current thread first, then dispatch order."""
        cur = self.current
        if (cur is not None and cur.state == READY
                and cur.pc < cur.trace_len and cur.busy_until <= cycle):
            yield cur
        for th in self._threads:
            if th is cur:
                continue
            if (th.state == READY and th.pc < th.trace_len
                    and th.busy_until <= cycle):
                yield th

    def _candidates_event(self):
        """Event-mode candidates: the maintained ready queue is already
        filtered (READY, non-busy, non-done) and in dispatch order, so this
        only has to overlay the GTO greedy-current priority.  The snapshot
        is safe: within one issue, the only queue mutation before ``break``
        is the removal of the thread currently being examined."""
        cur = self.current
        if cur is not None and cur.in_ready:
            yield cur
        for th in tuple(self._ready):
            if th is not cur and th.in_ready:
                yield th

    def _execute(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        if self.san is not None:
            self.san.on_execute(cycle, th, ins)
        op = ins.op
        cta = th.cta
        if op == isa.TMA_TENSOR:
            self.tma.submit_load(cycle, th, ins, nid)
        elif op == isa.TMA_STORE:
            self.tma.submit_store(cycle, th, ins, nid)
        elif op == isa.WGMMA:
            self.tc.push(cycle, th, ins, nid)
        elif op == isa.WGMMA_COMMIT:
            g = th.wgmma_groups.setdefault(ins.gid, [0, 0, False])
            if not g[2]:
                g[2] = True
                if g[1] < g[0]:
                    th.wgmma_out.add(ins.gid)
        elif op == isa.TMA_COMMIT:
            g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
            if not g[2]:
                g[2] = True
                if g[1] < g[0]:
                    th.tma_out.add(ins.gid)
        elif op == isa.RELEASE_STAGE:
            cta.stage_releases[ins.sid] = cta.stage_releases.get(ins.sid, 0) + 1
            self.notify_stage(cta, ins.sid)
        elif op == isa.BAR_ARRIVE:
            cta.bar_arrivals[ins.bid] = cta.bar_arrivals.get(ins.bid, 0) + 1
            self.notify_bar(cta, ins.bid)
        elif op == isa.BUBBLES:
            fl = self.faults
            until = cycle + (ins.cycles if fl is None
                             else fl.stretch(cycle, self.sm_id, ins.cycles))
            th.busy_until = until
            if self.event:
                # park on a per-SM timer: one coalesced wake per (cycle, SM)
                # instead of one broadcast wake_all per bubble
                if th.in_ready:
                    th.in_ready = False
                    self._ready.remove(th)
                lst = self._timers.get(until)
                if lst is None:
                    self._timers[until] = [th]
                    self.evq.wake_at(until, self._timer_fire)
                else:
                    lst.append(th)
            else:
                self.evq.push(until, self.wake_all)
        # waits that reached here had their condition met: no-op

    def _finish_thread(self, th: WGThread):
        th.cta.done_wgs += 1
        self._rebuild_threads()
        if th.cta.done_wgs == len(th.cta.threads):
            self._retire_cta(th.cta)

    def _retire_cta(self, cta: CTA):
        self.ctas.remove(cta)
        self._rebuild_threads()
        self.engine.cta_retired(self, cta)

    def all_blocked(self, cycle: int) -> bool:
        for th in self._threads:
            if (th.state == READY and th.pc < th.trace_len
                    and th.busy_until <= cycle):
                return False
        return True

    def unstall(self):
        """Re-mark stalled threads READY so conditions get re-checked.
        Broadcast-mode fallback only — waiter mode wakes via the index."""
        for th in self._threads:
            if th.state == STALLED:
                th.state = READY


class Engine:
    """Top level: CTA dispatcher + global cycle loop (Algorithm 1)."""

    SCHEDULERS = ("event", "waiter", "broadcast")

    def __init__(self, machine: GPUMachine, n_sms: Optional[int] = None,
                 mem_scale: Optional[float] = None, record_gantt: bool = False,
                 seed: int = 0, direct_hbm: bool = False, tracer=None,
                 broadcast_wake: bool = False,
                 scheduler: Optional[str] = None,
                 counters=None, sanitize: bool = False,
                 faults=None, watchdog=None):
        if scheduler is None:
            scheduler = "broadcast" if broadcast_wake else "event"
        elif scheduler not in self.SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; "
                             f"expected one of {self.SCHEDULERS}")
        elif broadcast_wake and scheduler != "broadcast":
            raise ValueError("broadcast_wake=True conflicts with "
                             f"scheduler={scheduler!r}")
        self.scheduler = scheduler
        self.cfg = machine
        self.n_sms = n_sms or machine.num_sms
        scale = mem_scale if mem_scale is not None else self.n_sms / machine.num_sms
        self.evq = EventQueue()
        self.lrc, self.l2, self.dram = build_memory(machine, self.evq, scale,
                                                    seed, direct=direct_hbm)
        self.tmaps: Dict[int, TensorMap] = {}
        self.tile_cache: Dict[tuple, list] = {}   # (map_id, origin) -> lines
        self.tile_seen: set = set()               # keys seen exactly once
        if tracer is None and record_gantt:
            # gantt is now a view over the structured event trace
            from repro.analysis.events import EventTracer
            tracer = EventTracer()
        self.tracer = tracer
        self.record_gantt = tracer is not None
        # opt-in PM-counter sink (obs.counters.CounterSink).  The run loops
        # only ever *read* engine state through it at window boundaries, so
        # attaching one cannot change simulated behavior (bit-neutrality is
        # enforced in tests/test_engine_equiv.py); when None the cost is a
        # single is-None test per loop iteration.
        self.counters = counters
        # opt-in runtime hazard sanitizer (analysis.hazards): TSan-style
        # per-event cross-check of the ring protocol, read-only over
        # simulated state like the counter sink, so bit-neutral by the
        # same argument; when off the cost is one is-None test per issue
        self.sanitizer = None
        if sanitize:
            from repro.analysis.hazards import HazardSanitizer
            self.sanitizer = HazardSanitizer()
        # populated by analysis.hazards.explain_deadlock the moment a run
        # loop concludes nothing can ever progress again (deadlocked=True);
        # deliberately NOT part of stats() — diagnostics, not simulation
        self.deadlock_info: Optional[dict] = None
        # opt-in seeded fault/variability session (repro.faults): latency
        # jitter, SM slowdown/offlining, throttle windows, delayed async
        # completions.  Same hook discipline as the counter sink: every
        # site costs one is-None test when off, and an identity plan draws
        # +0 extra cycles everywhere, so attaching it is bit-exact.  The
        # session's RNG is private — the engine RNG stream is untouched.
        self.faults = None
        if faults is not None:
            from repro.faults.session import make_session
            self.faults = make_session(faults, self.n_sms)
            fl = self.faults
            self.dram.faults = fl
            self.lrc.faults = fl
            if self.l2 is not self.lrc:     # sliced L2 (not DirectHBM)
                self.l2.faults = fl
                for sl in self.l2.slices:
                    sl.faults = fl
        # opt-in run watchdog (repro.faults.watchdog): wall-clock /
        # sim-cycle budgets with clean abort + partial-result salvage.
        # Read-only over simulated state; a run that finishes under budget
        # is bit-exact with an unwatched run.
        self.watchdog = None
        if watchdog is not None:
            from repro.faults.watchdog import make_watchdog
            self.watchdog = make_watchdog(watchdog)
        self.aborted = False
        self.abort_info: Optional[dict] = None
        self.broadcast_wake = scheduler == "broadcast"
        self.sms = [SM(i, machine, self) for i in range(self.n_sms)]
        self.pending: deque = deque()
        self.cycle = 0
        self.launched = 0
        self.retired = 0
        self.deadlocked = False
        self._active = set(range(self.n_sms))
        # event mode: the active set is a maintained ordered structure —
        # a min-heap of sm ids plus a membership flag per SM (no duplicate
        # entries), so the run loop drains it in ascending-id order instead
        # of re-sorting a set every iteration
        self._active_heap: List[int] = list(range(self.n_sms))
        self._active_flags = bytearray([1]) * self.n_sms

    # ------------------------------------------------------------------
    def define_tmap(self, tm: TensorMap):
        self.tmaps[tm.map_id] = tm

    def launch(self, ctas: List[CTATrace]):
        self.pending.extend(ctas)
        self._dispatch()

    def _dispatch(self, parent: Optional[int] = None):
        fl = self.faults
        off = fl.offline if fl is not None and fl.offline else None
        for sm in self.sms:
            if off is not None and sm.sm_id in off:
                continue                 # fenced/dead SM: no CTAs dispatched
            added = False
            while self.pending and sm.has_slot():
                trace = self.pending.popleft()
                cta = CTA(trace, self.launched)
                self.launched += 1
                sm.ctas.append(cta)
                for th in cta.threads:
                    th.sm = sm
                    if sm.event:
                        th.in_ready = True
                        insort(sm._ready, th, key=_ORDER)
                added = True
                if self.tracer is not None:
                    self.tracer.on_dispatch(cta.idx, parent)
                self.mark_active(sm)
            if added:
                sm._rebuild_threads()

    def cta_retired(self, sm: SM, cta: CTA):
        self.retired += 1
        if self.sanitizer is not None:
            self.sanitizer.on_cta_retired(self.cycle, cta)
        self._dispatch(parent=cta.idx)

    def mark_active(self, sm: SM):
        if sm.event:
            sid = sm.sm_id
            if not self._active_flags[sid]:
                self._active_flags[sid] = 1
                heappush(self._active_heap, sid)
            return
        self._active.add(sm.sm_id)
        if self.broadcast_wake:
            sm.unstall()

    # ------------------------------------------------------------------
    def run(self, max_cycles: int = 2_000_000_000) -> dict:
        if self.scheduler == "event":
            return self._run_event(max_cycles)
        broadcast = self.broadcast_wake
        active = self._active
        sms = self.sms
        evq = self.evq
        snk = self.counters
        wd = self.watchdog
        while self.cycle < max_cycles:
            evq.pop_ready(self.cycle)
            if snk is not None and self.cycle >= snk.next_sample:
                snk.sample(self.cycle, self)
            if self.retired == self.launched and not self.pending:
                break
            if wd is not None and wd.tripped(self.cycle):
                self._abort(wd)
                break
            progressed = False
            if active:
                # ascending sm id == the insertion-ordered small-int set
                # iteration the broadcast engine always produced
                for sid in sorted(active):
                    sm = sms[sid]
                    if sm.step(self.cycle):
                        progressed = True
                        sm.issue_cycles += 1
                    elif sm.all_blocked(self.cycle):
                        active.discard(sid)
            if progressed:
                self.cycle += 1
                continue
            nxt = evq.next_cycle()
            if nxt is None:
                # threads may be waiting on busy_until (bubbles) -- find min
                wake = [th.busy_until for sm in sms for th in sm.threads()
                        if th.state == READY and not th.done()
                        and th.busy_until > self.cycle]
                if not wake:
                    self._flag_deadlock()
                    break
                self.cycle = min(wake) if wd is None else wd.clamp(min(wake))
                for sm in sms:
                    self.mark_active(sm)
            else:
                nxt = max(self.cycle + 1, nxt)
                self.cycle = nxt if wd is None else wd.clamp(nxt)
                if broadcast:
                    # legacy rescan: re-mark every SM after each time jump
                    for sm in sms:
                        self.mark_active(sm)
        if snk is not None:
            snk.finish(self.cycle, self)
        return self.stats()

    def _run_event(self, max_cycles: int) -> dict:
        """Discrete-event run loop (default scheduler).

        Time advances straight to the next interesting cycle: the event-queue
        head (memory completions, busy-timer wakes, thread retirements) or
        the next cycle any SM can issue.  Nothing here scans threads:
        ``sm._ready`` is the maintained per-SM issue-eligible queue, the
        active set is a flag-guarded min-heap of SM ids drained in ascending
        order, and busy_until sleepers wake via coalesced per-SM timers —
        there is no broadcast wake and no O(threads) busy-scan fallback.

        The snapshot discipline matches the legacy loop exactly: the set of
        SMs stepped in a cycle is fixed before any of them steps, so an SM
        woken mid-sweep first issues on the following cycle."""
        sms = self.sms
        evq = self.evq
        heap = self._active_heap
        flags = self._active_flags
        snk = self.counters
        wd = self.watchdog
        while self.cycle < max_cycles:
            evq.pop_ready(self.cycle)
            if snk is not None and self.cycle >= snk.next_sample:
                snk.sample(self.cycle, self)
            if self.retired == self.launched and not self.pending:
                break
            if wd is not None and wd.tripped(self.cycle):
                self._abort(wd)
                break
            progressed = False
            if heap:
                snapshot = []
                while heap:                 # ascending sm id
                    sid = heappop(heap)
                    flags[sid] = 0
                    snapshot.append(sid)
                for sid in snapshot:
                    sm = sms[sid]
                    if sm._ready:
                        if sm.step(self.cycle):
                            progressed = True
                            sm.issue_cycles += 1
                        if sm._ready and not flags[sid]:
                            flags[sid] = 1
                            heappush(heap, sid)
            if progressed:
                self.cycle += 1
                continue
            nxt = evq.next_cycle()
            if nxt is None:
                # no issuable thread, no pending event: nothing can ever
                # make progress again (busy sleepers hold queue timers)
                self._flag_deadlock()
                break
            nxt = max(self.cycle + 1, nxt)
            self.cycle = nxt if wd is None else wd.clamp(nxt)
        if snk is not None:
            snk.finish(self.cycle, self)
        return self.stats()

    # ------------------------------------------------------------------
    def _abort(self, wd):
        """Watchdog trip: break the run loop cleanly and salvage a partial
        result (CTA census, blocked-thread snapshot, fault stats) instead
        of hanging or dying.  Counters still get their finish() sample —
        the loops run it after the break — so PM timelines up to the abort
        survive too."""
        from repro.faults.watchdog import salvage
        self.aborted = True
        self.abort_info = salvage(self, wd.reason, wd.wall_s())

    def _flag_deadlock(self):
        """Both run loops land here when nothing can ever progress again.
        Attaches the wait-for-graph explanation (which thread blocks on
        which sid/bid, witness cycle) instead of just flipping the bool;
        runs after the loop already decided to break, so it cannot perturb
        simulated state."""
        self.deadlocked = self.retired < self.launched
        if self.deadlocked:
            from repro.analysis.hazards import explain_deadlock
            self.deadlock_info = explain_deadlock(self)

    def stats(self) -> dict:
        l2 = self.l2.stats()
        tc_busy = sum(sm.tc.busy_cycles for sm in self.sms)
        return {
            "cycles": self.cycle,
            "time_us": self.cycle / (self.cfg.freq_ghz * 1e3),
            "ctas": self.retired,
            "l2": l2,
            "l2_req_bytes": l2["requests"] * self.cfg.line_bytes,
            "dram_bytes": self.dram.bytes_served,
            "lrc_merged": self.lrc.merged,
            "tma_lines": sum(sm.tma.lines_issued for sm in self.sms),
            "tc_busy_cycles": tc_busy,
            "tc_util": tc_busy / max(1, self.cycle * self.n_sms),
        }

    def gantt(self) -> List[Tuple[str, int, int]]:
        """Legacy flat-interval view, derived from the structured trace."""
        if self.tracer is None:
            return []
        from repro.core.gantt import from_events
        return from_events(self.tracer.events)
