"""Sim-FA core: event-driven, WarpGroup-granular cycle-level engine.

Implements the paper's Algorithm 1:
  * each WarpGroup is a *logical thread* with a single instruction flow;
  * the Scheduler dispatches logical threads (grouped in CTAs) to physical
    SM slots under the occupancy limit, and plays warp-scheduler (GTO)
    among resident threads;
  * the Frontend issues in order, executes out of order: async ops are
    handed to the TMA / TensorCore engines, waits with unmet conditions
    roll the PC back and park the thread on a waiter list (AEQ);
  * mbarriers, pipeline stages (producer_acquire / consumer_release),
    WGMMA commit groups, TMA store groups and named barriers are modeled
    in full — the paper found incomplete barrier modeling breaks overlap
    estimation (§4.1).

Timing jumps between "interesting" cycles (event completions / ready
threads); it never ticks idle cycles, which is what makes a Python
implementation viable where the paper uses C++.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import isa
from repro.core.isa import Instr, TensorMap
from repro.core.machine import GPUMachine
from repro.core.memory import EventQueue, build_memory

READY, STALLED, DONE = 0, 1, 2


@dataclass
class CTATrace:
    """One thread block: a list of WarpGroup instruction traces."""
    wgs: List[List[Instr]]
    n_consumers: int = 2
    name: str = ""


class WGThread:
    __slots__ = ("trace", "pc", "state", "cta", "wg_id", "sm", "busy_until",
                 "wgmma_groups", "tma_groups", "mb_expected", "acq_count",
                 "bar_count", "label")

    def __init__(self, trace, cta, wg_id):
        self.trace = trace
        self.pc = 0
        self.state = READY
        self.cta = cta
        self.wg_id = wg_id
        self.sm = None
        self.busy_until = 0
        # per-WG async group bookkeeping: gid -> [issued, completed, committed]
        self.wgmma_groups: Dict[int, List] = {}
        self.tma_groups: Dict[int, List] = {}
        self.mb_expected: Dict[int, int] = {}
        self.acq_count: Dict[int, int] = {}
        self.bar_count: Dict[int, int] = {}
        self.label = ""

    def done(self):
        return self.pc >= len(self.trace)


class CTA:
    __slots__ = ("trace", "threads", "mbarrier", "stage_releases",
                 "bar_arrivals", "n_consumers", "idx", "done_wgs")

    def __init__(self, trace: CTATrace, idx: int):
        self.trace = trace
        self.idx = idx
        self.n_consumers = trace.n_consumers
        self.threads = [WGThread(t, self, i) for i, t in enumerate(trace.wgs)]
        for i, t in enumerate(self.threads):
            t.label = f"cta{idx}/wg{i}"
        self.mbarrier: Dict[int, int] = {}        # sid -> completed signals
        self.stage_releases: Dict[int, int] = {}  # sid -> consumer releases
        self.bar_arrivals: Dict[int, int] = {}    # bid -> arrivals
        self.done_wgs = 0


class TensorCoreEngine:
    """Single tensor-core pipeline + WGMMA issue buffer per SM (§4.2)."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.buffer: List[Tuple[WGThread, Instr, int]] = []
        self.busy_until = 0
        self.busy_cycles = 0

    def can_accept(self) -> bool:
        return len(self.buffer) < self.cfg.wgmma_issue_buffer

    def push(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        g = th.wgmma_groups.setdefault(ins.gid, [0, 0, False])
        g[0] += 1
        self.buffer.append((th, ins, nid))
        self._pump(cycle)

    def _pump(self, cycle: int):
        if not self.buffer:
            return
        start = max(cycle, self.busy_until)
        th, ins, nid = self.buffer.pop(0)
        # GPU mode: FP16 m64nNk16 completes in ~N/2 cycles (paper §4.2);
        # TPU mode: the tracegen precomputes MXU cycles into ins.cycles.
        dur = ins.cycles if ins.cycles > 0 else max(
            1, int(round(ins.n / self.cfg.wgmma_n_cycles_divisor)))
        self.busy_until = start + dur
        self.busy_cycles += dur
        if self.sm.tracer is not None:
            self.sm.tracer.on_mma(nid, th, ins, start, start + dur)

        def complete():
            g = th.wgmma_groups[ins.gid]
            g[1] += 1
            self.sm.wake_all()
            self._pump(self.busy_until)

        self.evq.push(start + dur, complete)


class TMAEngine:
    """Per-SM TMA engine: descriptor setup, HW address generation with line
    dedup, bounded in-flight lines, mbarrier signaling (§4.3)."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, sm, lrc, tmaps):
        self.cfg = cfg
        self.evq = evq
        self.sm = sm
        self.lrc = lrc
        self.tmaps = tmaps
        self.inflight = 0
        self.jobs: List[dict] = []
        self.lines_issued = 0
        self._kick_scheduled = False
        self._issue_cycle = -1
        self._issued_in_cycle = 0

    def submit_load(self, cycle: int, th: WGThread, ins: Instr,
                    nid: int = -1):
        tm: TensorMap = self.tmaps[ins.map_id]
        lines = tm.tile_lines(ins.origin, self.cfg.line_bytes,
                              dedup=self.cfg.tma_dedup)
        # Fig. 2: non-tensor bulk requests bypass the descriptor cache and
        # TensorMap setup path -> only the common launch latency applies.
        setup = self.cfg.tma_launch_latency + (
            0 if ins.bulk else self.cfg.tma_tmap_setup_latency)
        job = {"lines": list(lines), "left": len(lines), "th": th,
               "sid": ins.sid, "write": False, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        self.evq.push(cycle + setup, lambda: self._start(job))

    def submit_store(self, cycle: int, th: WGThread, ins: Instr,
                     nid: int = -1):
        tm: TensorMap = self.tmaps[ins.map_id]
        lines = tm.tile_lines(ins.origin, self.cfg.line_bytes,
                              dedup=self.cfg.tma_dedup)
        g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
        g[0] += 1
        # stores bypass the TensorMap setup path only when bulk (Fig. 2);
        # FA3's O store uses a TensorMap -> full setup
        setup = self.cfg.tma_launch_latency + self.cfg.tma_tmap_setup_latency
        job = {"lines": list(lines), "left": len(lines), "th": th,
               "gid": ins.gid, "write": True, "tag": ins.tag, "t0": cycle,
               "inflight": 0, "nid": nid, "setup": setup}
        self.evq.push(cycle + setup, lambda: self._start(job))

    def _start(self, job):
        self.jobs.append(job)
        self._issue(self._now())

    def _now(self):
        return self.sm.engine.cycle

    def _issue(self, cycle: int):
        """Issue up to tma_lines_per_cycle lines this cycle, round-robin over
        in-flight TMA ops; max_inflight_lines bounds each op's outstanding
        lines (several ops stream concurrently through the ring buffer)."""
        if cycle > self._issue_cycle:
            self._issue_cycle = cycle
            self._issued_in_cycle = 0
        issued = 0
        self.jobs = [j for j in self.jobs if j["lines"] or j["inflight"]]
        for job in list(self.jobs):
            if self._issued_in_cycle >= self.cfg.tma_lines_per_cycle:
                break
            while (job["lines"]
                   and self._issued_in_cycle < self.cfg.tma_lines_per_cycle
                   and job["inflight"] < self.cfg.tma_max_inflight_lines):
                line = job["lines"].pop(0)
                job["inflight"] += 1
                self.inflight += 1
                self.lines_issued += 1
                issued += 1
                self._issued_in_cycle += 1

                def done(job=job):
                    self.inflight -= 1
                    job["inflight"] -= 1
                    job["left"] -= 1
                    if job["left"] == 0:
                        self._finish(job)
                    self._issue(self._now())

                self.lrc.request(cycle, line, self.sm.sm_id, done,
                                 write=job["write"])
        # rate-limited this cycle with lines still issuable: kick next cycle.
        # (inflight-capped jobs are re-kicked by their done() callbacks)
        if (self._issued_in_cycle >= self.cfg.tma_lines_per_cycle
                and any(j["lines"] and
                        j["inflight"] < self.cfg.tma_max_inflight_lines
                        for j in self.jobs)
                and not self._kick_scheduled):
            self._kick_scheduled = True

            def kick():
                self._kick_scheduled = False
                self._issue(self._now())

            self.evq.push(cycle + 1, kick)

    def _finish(self, job):
        th: WGThread = job["th"]
        signal_n = 0
        if job["write"]:
            g = th.tma_groups[job["gid"]]
            g[1] += 1
        else:
            cta = th.cta
            cta.mbarrier[job["sid"]] = cta.mbarrier.get(job["sid"], 0) + 1
            signal_n = cta.mbarrier[job["sid"]]
        if self.sm.tracer is not None:
            self.sm.tracer.on_tma(
                job["nid"], th, write=job["write"], tag=job["tag"],
                t0=job["t0"], t1=self._now(), fixed=job["setup"],
                sid=job.get("sid", -1), gid=job.get("gid", -1),
                signal_n=signal_n)
        self.sm.wake_all()


class SM:
    def __init__(self, sm_id: int, cfg: GPUMachine, engine):
        self.sm_id = sm_id
        self.cfg = cfg
        self.engine = engine
        self.evq = engine.evq
        self.tracer = engine.tracer
        self.ctas: List[CTA] = []
        self.tc = TensorCoreEngine(cfg, self.evq, self)
        self.tma = TMAEngine(cfg, self.evq, self, engine.lrc, engine.tmaps)
        self.current: Optional[WGThread] = None   # GTO greedy pointer
        self.issue_cycles = 0

    # ------------------------------------------------------------------
    def threads(self):
        for cta in self.ctas:
            yield from cta.threads

    def wake_all(self):
        self.engine.mark_active(self)

    def has_slot(self) -> bool:
        return len(self.ctas) < self.cfg.occupancy_limit

    # ------------------------------------------------------------------
    # condition checks for blocking instructions
    def _cond_met(self, th: WGThread, ins: Instr) -> bool:
        cta = th.cta
        op = ins.op
        if op == isa.MB_WAIT:
            need = th.mb_expected.get(ins.sid, 0) + 1
            return cta.mbarrier.get(ins.sid, 0) >= need
        if op == isa.ACQUIRE_STAGE:
            use = th.acq_count.get(ins.sid, 0)
            if use == 0:
                return True
            return cta.stage_releases.get(ins.sid, 0) >= use * cta.n_consumers
        if op == isa.WGMMA_WAIT:
            groups = th.wgmma_groups
            outstanding = sum(
                1 for g, (iss, comp, com) in groups.items()
                if g <= ins.gid and com and comp < iss)
            return outstanding <= ins.n
        if op == isa.TMA_WAIT:
            groups = th.tma_groups
            outstanding = sum(
                1 for g, (iss, comp, com) in groups.items()
                if g <= ins.gid and com and comp < iss)
            return outstanding <= ins.n
        if op == isa.BAR_WAIT:
            return cta.bar_arrivals.get(ins.bid, 0) >= ins.n
        if op == isa.WGMMA:
            return self.tc.can_accept()
        return True

    def _apply_blocking(self, th: WGThread, ins: Instr):
        if ins.op == isa.MB_WAIT:
            th.mb_expected[ins.sid] = th.mb_expected.get(ins.sid, 0) + 1
        elif ins.op == isa.ACQUIRE_STAGE:
            th.acq_count[ins.sid] = th.acq_count.get(ins.sid, 0) + 1

    # ------------------------------------------------------------------
    def step(self, cycle: int) -> bool:
        """Issue up to issue_width instructions. Returns True if progressed."""
        progressed = False
        for _ in range(self.cfg.issue_width):
            issued = False
            for th in self._candidates(cycle):
                ins = th.trace[th.pc]
                if not self._cond_met(th, ins):
                    th.state = STALLED   # PC rollback: do not advance
                    if self.current is th:
                        self.current = None
                    continue             # GTO: fall through to next-oldest
                # trace before counters mutate: dep ordinals snapshot here
                nid = (self.tracer.on_issue(cycle, th, ins)
                       if self.tracer is not None else -1)
                self._apply_blocking(th, ins)
                self._execute(cycle, th, ins, nid)
                th.pc += 1
                self.current = th        # greedy: keep issuing this thread
                issued = True
                if th.done():
                    th.state = DONE
                    self.current = None
                    # retirement waits for trailing in-flight work (bubbles)
                    fin = max(cycle, th.busy_until)
                    if fin > cycle:
                        self.evq.push(fin, self._finish_thread, th)
                    else:
                        self._finish_thread(th)
                break
            if not issued:
                break
            progressed = True
        return progressed

    def _candidates(self, cycle: int):
        """Greedy-then-oldest order: current thread first, then dispatch order."""
        cur = self.current
        if (cur is not None and cur.state == READY and not cur.done()
                and cur.busy_until <= cycle):
            yield cur
        for th in self.threads():
            if th is cur:
                continue
            if th.state == READY and not th.done() and th.busy_until <= cycle:
                yield th

    def _execute(self, cycle: int, th: WGThread, ins: Instr, nid: int = -1):
        op = ins.op
        cta = th.cta
        if op == isa.TMA_TENSOR:
            self.tma.submit_load(cycle, th, ins, nid)
        elif op == isa.TMA_STORE:
            self.tma.submit_store(cycle, th, ins, nid)
        elif op == isa.WGMMA:
            self.tc.push(cycle, th, ins, nid)
        elif op == isa.WGMMA_COMMIT:
            g = th.wgmma_groups.setdefault(ins.gid, [0, 0, False])
            g[2] = True
        elif op == isa.TMA_COMMIT:
            g = th.tma_groups.setdefault(ins.gid, [0, 0, False])
            g[2] = True
        elif op == isa.RELEASE_STAGE:
            cta.stage_releases[ins.sid] = cta.stage_releases.get(ins.sid, 0) + 1
            self.wake_all()
        elif op == isa.BAR_ARRIVE:
            cta.bar_arrivals[ins.bid] = cta.bar_arrivals.get(ins.bid, 0) + 1
            self.wake_all()
        elif op == isa.BUBBLES:
            th.busy_until = cycle + ins.cycles
            self.evq.push(th.busy_until, self.wake_all)
        # waits that reached here had their condition met: no-op

    def _finish_thread(self, th: WGThread):
        th.cta.done_wgs += 1
        if th.cta.done_wgs == len(th.cta.threads):
            self._retire_cta(th.cta)

    def _retire_cta(self, cta: CTA):
        self.ctas.remove(cta)
        self.engine.cta_retired(self, cta)

    def all_blocked(self, cycle: int) -> bool:
        for th in self.threads():
            if th.state == READY and not th.done() and th.busy_until <= cycle:
                return False
        return True

    def unstall(self):
        """Re-mark stalled threads READY so conditions get re-checked."""
        for th in self.threads():
            if th.state == STALLED:
                th.state = READY


class Engine:
    """Top level: CTA dispatcher + global cycle loop (Algorithm 1)."""

    def __init__(self, machine: GPUMachine, n_sms: Optional[int] = None,
                 mem_scale: Optional[float] = None, record_gantt: bool = False,
                 seed: int = 0, direct_hbm: bool = False, tracer=None):
        self.cfg = machine
        self.n_sms = n_sms or machine.num_sms
        scale = mem_scale if mem_scale is not None else self.n_sms / machine.num_sms
        self.evq = EventQueue()
        self.lrc, self.l2, self.dram = build_memory(machine, self.evq, scale,
                                                    seed, direct=direct_hbm)
        self.tmaps: Dict[int, TensorMap] = {}
        if tracer is None and record_gantt:
            # gantt is now a view over the structured event trace
            from repro.analysis.events import EventTracer
            tracer = EventTracer()
        self.tracer = tracer
        self.record_gantt = tracer is not None
        self.sms = [SM(i, machine, self) for i in range(self.n_sms)]
        self.pending: List[CTATrace] = []
        self.cycle = 0
        self.launched = 0
        self.retired = 0
        self.deadlocked = False
        self._active = set(range(self.n_sms))

    # ------------------------------------------------------------------
    def define_tmap(self, tm: TensorMap):
        self.tmaps[tm.map_id] = tm

    def launch(self, ctas: List[CTATrace]):
        self.pending.extend(ctas)
        self._dispatch()

    def _dispatch(self, parent: Optional[int] = None):
        for sm in self.sms:
            while self.pending and sm.has_slot():
                trace = self.pending.pop(0)
                cta = CTA(trace, self.launched)
                self.launched += 1
                sm.ctas.append(cta)
                for th in cta.threads:
                    th.sm = sm
                if self.tracer is not None:
                    self.tracer.on_dispatch(cta.idx, parent)
                self.mark_active(sm)

    def cta_retired(self, sm: SM, cta: CTA):
        self.retired += 1
        self._dispatch(parent=cta.idx)

    def mark_active(self, sm: SM):
        self._active.add(sm.sm_id)
        sm.unstall()

    # ------------------------------------------------------------------
    def run(self, max_cycles: int = 2_000_000_000) -> dict:
        while self.cycle < max_cycles:
            self.evq.pop_ready(self.cycle)
            if self.retired == self.launched and not self.pending:
                break
            progressed = False
            for sid in list(self._active):
                sm = self.sms[sid]
                if sm.step(self.cycle):
                    progressed = True
                    sm.issue_cycles += 1
                elif sm.all_blocked(self.cycle):
                    self._active.discard(sid)
            if progressed:
                self.cycle += 1
                continue
            nxt = self.evq.next_cycle()
            if nxt is None:
                # threads may be waiting on busy_until (bubbles) -- find min
                wake = [th.busy_until for sm in self.sms for th in sm.threads()
                        if th.state == READY and not th.done()
                        and th.busy_until > self.cycle]
                if not wake:
                    self.deadlocked = self.retired < self.launched
                    break
                self.cycle = min(wake)
            else:
                self.cycle = max(self.cycle + 1, nxt)
            for sm in self.sms:
                self.mark_active(sm)
        return self.stats()

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        l2 = self.l2.stats()
        tc_busy = sum(sm.tc.busy_cycles for sm in self.sms)
        return {
            "cycles": self.cycle,
            "time_us": self.cycle / (self.cfg.freq_ghz * 1e3),
            "ctas": self.retired,
            "l2": l2,
            "l2_req_bytes": l2["requests"] * self.cfg.line_bytes,
            "dram_bytes": self.dram.bytes_served,
            "lrc_merged": self.lrc.merged,
            "tma_lines": sum(sm.tma.lines_issued for sm in self.sms),
            "tc_busy_cycles": tc_busy,
            "tc_util": tc_busy / max(1, self.cycle * self.n_sms),
        }

    def gantt(self) -> List[Tuple[str, int, int]]:
        """Legacy flat-interval view, derived from the structured trace."""
        if self.tracer is None:
            return []
        from repro.core.gantt import from_events
        return from_events(self.tracer.events)
