"""SimFA-python: the paper's analytical traffic/performance model (§3).

Implements Eq. (1)-(12) exactly, including the two-regime DRAM model with
the concurrency-aware wave factor (Eq. 5-6) that ideal-cache models miss.
Notation follows Table 1 (B, L, S, H_kv, G, D, T_M, P, N_SM, O_limit).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Union

from repro.configs.llama3 import AttnWorkload
from repro.core.kprog import registry as kernel_registry
from repro.core.kprog.costs import DEFAULT_T_N
from repro.core.machine import GPUMachine


@dataclass(frozen=True)
class TrafficReport:
    flops: float                 # Eq. (1)
    l2_bytes: float              # Eq. (2)
    dram_ideal_bytes: float      # Eq. (3)
    dram_real_bytes: float       # Eq. (6)
    ideal_regime: bool           # Eq. (4)
    waves_per_group: int         # Eq. (5)
    traffic_ratio: float         # Eq. (7)
    intensity_l2: float          # Eq. (11)
    intensity_approx: float      # Eq. (12)
    # time estimates (seconds) for the roofline composition
    t_compute: float = 0.0
    t_l2: float = 0.0
    t_dram: float = 0.0
    # pipeline fill/drain: the first tile must traverse TMA setup + memory
    # latency + two MMA/softmax stages before steady state; dominates small
    # single-wave launches where throughput rooflines are optimistic
    t_ramp: float = 0.0

    @property
    def dram_bytes(self) -> float:
        return self.dram_ideal_bytes if self.ideal_regime else self.dram_real_bytes

    @property
    def latency(self) -> float:
        return max(self.t_compute, self.t_l2, self.t_dram) + self.t_ramp

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "l2": self.t_l2, "dram": self.t_dram}
        return max(terms, key=terms.get)


def total_flops(w: AttnWorkload) -> float:
    """Eq. (1): 4 * B * (H_kv*G) * L * S * D (non-causal)."""
    f = 4.0 * w.B * (w.H_kv * w.G) * w.L * w.S * w.D
    return f / 2 if w.causal else f


def l2_traffic(w: AttnWorkload, t_m: int) -> float:
    """Eq. (2): P*B*(H_kv*G)*D*(2L + ceil(L/T_M)*2S)."""
    s_eff = w.S / 2 if w.causal else w.S
    return w.P * w.B * (w.H_kv * w.G) * w.D * (
        2 * w.L + math.ceil(w.L / t_m) * 2 * s_eff)


def dram_ideal(w: AttnWorkload) -> float:
    """Eq. (3): read Q,K,V once, write O once."""
    return w.P * w.B * w.D * (2 * (w.H_kv * w.G) * w.L + 2 * w.H_kv * w.S)


def ideal_condition(w: AttnWorkload, l2_bytes_effective: float) -> bool:
    """Eq. (4): one K head + one V head must fit the effective L2."""
    return l2_bytes_effective > 2 * w.P * w.S * w.D


def waves_per_group(w: AttnWorkload, t_m: int, n_sm: int, o_limit: int) -> int:
    """Eq. (5): memory passes over one KV group."""
    return max(1, math.ceil(w.G * math.ceil(w.L / t_m) / (n_sm * o_limit)))


def dram_real(w: AttnWorkload, t_m: int, n_sm: int, o_limit: int) -> float:
    """Eq. (6): Q/O base traffic + KV refetched once per wave."""
    base = 2 * w.P * w.B * (w.H_kv * w.G) * w.L * w.D
    kv = 2 * w.P * w.B * w.H_kv * w.S * w.D
    return base + kv * waves_per_group(w, t_m, n_sm, o_limit)


def analyze(w: AttnWorkload, cfg: GPUMachine, *, t_m: int = 64,
            t_n: Optional[int] = None, tiling=None,
            kernel: Union[str, "object"] = "fa3",
            l2_effective_fraction: float = 0.5,
            l2_bw_bytes_per_s: Optional[float] = None) -> TrafficReport:
    """Full SimFA-python report for one attention kernel invocation.

    The traffic terms go through the registered kernel's hooks so Eq. 2/6
    specialize per scenario (``kernel="fa3"`` reproduces the paper's
    closed forms above exactly).  Pass the same ``tiling`` the simulation
    used and the hooks (and the ``t_m``/``t_n`` the ramp term charges)
    follow it; otherwise the kernel's default tiling applies (paper
    reference 64x176 for FA3).  l2_effective_fraction=0.5 follows §6.2.2:
    half the nominal L2 is used as the effective boundary on
    partitioned-L2 parts (H800).
    """
    spec = kernel_registry.get(kernel)
    if tiling is not None:
        t_m = getattr(tiling, "t_m", t_m)
        if t_n is None:
            t_n = getattr(tiling, "t_n", None)
    fl = spec.flops(w)
    l2b = spec.l2_traffic(w, t_m, tiling=tiling)
    ideal_b = spec.dram_ideal(w)
    wgrp = waves_per_group(w, t_m, cfg.num_sms, cfg.occupancy_limit)
    real_b = spec.dram_real(w, t_m, cfg.num_sms, cfg.occupancy_limit,
                            tiling=tiling)
    ideal = ideal_condition(w, cfg.l2_bytes * l2_effective_fraction)
    dram_b = ideal_b if ideal else real_b

    # Eq. (7), (11), (12)
    ratio = l2b / max(dram_b, 1.0)
    inten = fl / max(l2b, 1.0)
    inten_apx = 2.0 * t_m / w.P

    # roofline composition; L2 bandwidth defaults to the TMA-path aggregate
    # (num_sms * inflight/latency * line) — see core/memory.py calibration
    peak = cfg.peak_tflops_fp16 * 1e12
    if l2_bw_bytes_per_s is None:
        lines_per_cycle = (cfg.tma_max_inflight_lines / cfg.l2_near_latency
                           * cfg.num_sms)
        l2_bw_bytes_per_s = lines_per_cycle * cfg.line_bytes * cfg.freq_ghz * 1e9
    t_c = fl / peak
    t_l2 = l2b / l2_bw_bytes_per_s
    t_d = dram_b / (cfg.dram_bw_gbps * 1e9)

    # fill/drain: TMA setup + memory round trip for the first K tile, plus
    # two (softmax + MMA) stages before/after steady state; the bubble is
    # the same §5.2 cost the trace generators charge (shared in
    # kprog.costs), shaped by the dispatched kernel at the tiling's t_n
    if t_n is None:
        t_n = getattr(spec.default_tiling(), "t_n", DEFAULT_T_N)
    bubble = spec.ramp_bubble_cycles(cfg, w, t_m, t_n)
    mma = (w.D // 16) * max(1, int(t_n / cfg.wgmma_n_cycles_divisor)) / 8
    ramp_cycles = (cfg.tma_launch_latency + cfg.tma_tmap_setup_latency
                   + cfg.l2_near_latency + cfg.dram_latency
                   + 2 * (bubble + mma))
    t_ramp = ramp_cycles / (cfg.freq_ghz * 1e9)
    return TrafficReport(
        flops=fl, l2_bytes=l2b, dram_ideal_bytes=ideal_b,
        dram_real_bytes=real_b, ideal_regime=ideal, waves_per_group=wgrp,
        traffic_ratio=ratio, intensity_l2=inten, intensity_approx=inten_apx,
        t_compute=t_c, t_l2=t_l2, t_dram=t_d, t_ramp=t_ramp)
