"""Pipeline Gantt chart extraction (paper Fig. 7).

The raw data now lives in the structured event trace
(:mod:`repro.analysis.events`); this module is a *view* that flattens
``PipeEvent`` records back into ``(tag, start, end)`` intervals for the text
chart and external plotting.
"""
from __future__ import annotations

from typing import List, Tuple

# tag parsing lives in obs.labels (single source of truth for the
# cta{i}/{role} + {lane}:{label}:{tag} conventions); lane_of is
# re-exported here for back-compat
from repro.obs.labels import label_of, lane_of, make_label  # noqa: F401
from repro.obs.labels import split_gantt_tag

LANES = ("tma", "mma", "bubble")


def from_events(events) -> List[Tuple[str, int, int]]:
    """Flatten PipeEvents into the legacy gantt tuples (engine-occupancy
    intervals only: TMA jobs, tensor-core execution, softmax bubbles)."""
    out: List[Tuple[str, int, int]] = []
    for ev in events:
        if ev.kind == "mma":
            out.append((f"mma:{ev.label}:{ev.tag}", ev.t0, ev.t1))
        elif ev.kind == "tma":
            out.append((f"tma:{ev.label}:{ev.tag}", ev.t0, ev.t1))
        elif ev.kind == "bubble":
            out.append((f"bubble:{ev.label}", ev.t0, ev.t1))
    return out


def filter_sm(gantt: List[Tuple[str, int, int]], cta_ids=(0, 1)):
    """Keep intervals belonging to the given CTA ids (one SM's residents)."""
    keep = tuple(make_label(i, "") for i in cta_ids)
    return [g for g in gantt if any(k in g[0] for k in keep)]


def render_text(gantt: List[Tuple[str, int, int]], width: int = 100,
                t_max: int = 0) -> str:
    """ASCII Gantt: one row per (lane, warpgroup)."""
    if not gantt:
        return "(empty gantt)"
    t_end = t_max or max(e for _, _, e in gantt)
    rows = {}
    for tag, s, e in gantt:
        lane, wg, _ = split_gantt_tag(tag)
        key = f"{wg or '?'}:{lane}"
        rows.setdefault(key, []).append((s, e))
    out = []
    for key in sorted(rows):
        line = [" "] * width
        for s, e in rows[key]:
            a = min(width - 1, int(s / t_end * width))
            b = min(width, max(a + 1, int(e / t_end * width)))
            ch = {"tma": "=", "mma": "#", "bubble": "~"}.get(key.split(":")[-1], "*")
            for i in range(a, b):
                line[i] = ch
        out.append(f"{key:24s}|{''.join(line)}|")
    out.append(f"{'legend':24s}|= TMA   # WGMMA   ~ softmax bubbles; "
               f"0..{t_end} cycles|")
    return "\n".join(out)
