"""Sim-FA instruction set (paper Table 3).

Instructions are lightweight tuples (opcode + operands) produced by the
trace generators and consumed by the engine. ``sid`` indexes mbarriers /
ring-buffer stages, ``gid`` async commit groups, ``bid`` named barriers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

# opcodes
DEF_TMAP = "DEF_TMAP"
TMA_TENSOR = "TMA_TENSOR"          # async HBM->SMEM tile load, signals sid
MB_WAIT = "MB_WAIT"                # mbarrier.try_wait on sid
ACQUIRE_STAGE = "ACQUIRE_STAGE"    # pipeline.producer_acquire
RELEASE_STAGE = "RELEASE_STAGE"    # pipeline.consumer_release
TMA_STORE = "TMA_STORE"            # async SMEM->HBM store in group gid
TMA_COMMIT = "TMA_COMMIT"
TMA_WAIT = "TMA_WAIT"              # block until <=N groups outstanding
WGMMA = "WGMMA"                    # async MMA MxNxK into group gid
WGMMA_COMMIT = "WGMMA_COMMIT"
WGMMA_WAIT = "WGMMA_WAIT"
BAR_ARRIVE = "BAR_ARRIVE"          # named barrier non-blocking signal
BAR_WAIT = "BAR_WAIT"              # block until >=k arrives
BUBBLES = "BUBBLES"                # CUDA-core work (softmax etc.)

# Well-known operand values shared by the trace generators and engine-side
# tooling.  Point-to-point tokens (e.g. "Q tile ready") use mbarrier sids
# allocated upward from Q_READY_SID, far above the ring-buffer stage sids
# (allocated upward from 0), so the two namespaces cannot collide; epilogue
# TMA store groups use EPILOGUE_GID, far above any WGMMA commit-group id.
Q_READY_SID = 98                   # first point-to-point token sid
EPILOGUE_GID = 99                  # epilogue TMA store commit group


@dataclass(frozen=True)
class TensorMap:
    """cuTensorMapEncodeTiled analogue: enough metadata for hardware address
    generation of a box (tile) anywhere in a strided tensor."""
    map_id: int
    base: int                      # byte address
    dims: Tuple[int, ...]          # logical tensor dims (row-major outer..inner)
    strides: Tuple[int, ...]       # byte strides per dim
    box: Tuple[int, ...]           # tile shape in elements
    esz: int                       # element size in bytes

    def tile_lines(self, origin: Tuple[int, ...], line_bytes: int,
                   dedup: bool = True):
        """Generate the cache-line addresses touched by the tile at
        ``origin``. With dedup=False, address generation is per *element*
        ("If we generate requests for each element, many duplicate requests
        will be generated" — §5.4): every element emits a request for its
        containing line (ablation: 'No line deduplication', paper Table 5)."""
        # innermost dim assumed contiguous (stride == esz)
        inner = self.box[-1] * self.esz
        lines = []
        seen = set()

        def rec(dim, addr):
            if dim == len(self.box) - 1:
                if dedup:
                    start = addr
                    end = addr + inner
                    a = (start // line_bytes) * line_bytes
                    while a < end:
                        if a not in seen:
                            seen.add(a)
                            lines.append(a)
                        a += line_bytes
                else:
                    for e in range(self.box[-1]):
                        a = addr + e * self.esz
                        lines.append((a // line_bytes) * line_bytes)
                return
            for i in range(self.box[dim]):
                rec(dim + 1, addr + (origin[dim] + i) * self.strides[dim])

        rec(0, self.base + origin[-1] * self.esz)
        return lines


@dataclass(frozen=True)
class Instr:
    op: str
    # generic operand fields (interpretation depends on op)
    sid: int = -1
    gid: int = -1
    bid: int = -1
    n: int = 0                      # WGMMA_WAIT/TMA_WAIT N; BAR_WAIT k
    m: int = 0                      # WGMMA M
    k: int = 0                      # WGMMA K
    cycles: int = 0                 # BUBBLES
    map_id: int = -1                # TMA ops
    origin: Tuple[int, ...] = ()    # TMA tile origin
    bulk: bool = False              # non-tensor bulk copy: skips the
                                    # descriptor-cache/TensorMap setup (Fig. 2)
    tag: str = ""                   # debug label (e.g. "K", "V", "QK", "PV")
