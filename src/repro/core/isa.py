"""Sim-FA instruction set (paper Table 3).

Instructions are lightweight tuples (opcode + operands) produced by the
trace generators and consumed by the engine. ``sid`` indexes mbarriers /
ring-buffer stages, ``gid`` async commit groups, ``bid`` named barriers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

# opcodes
DEF_TMAP = "DEF_TMAP"
TMA_TENSOR = "TMA_TENSOR"          # async HBM->SMEM tile load, signals sid
MB_WAIT = "MB_WAIT"                # mbarrier.try_wait on sid
ACQUIRE_STAGE = "ACQUIRE_STAGE"    # pipeline.producer_acquire
RELEASE_STAGE = "RELEASE_STAGE"    # pipeline.consumer_release
TMA_STORE = "TMA_STORE"            # async SMEM->HBM store in group gid
TMA_COMMIT = "TMA_COMMIT"
TMA_WAIT = "TMA_WAIT"              # block until <=N groups outstanding
WGMMA = "WGMMA"                    # async MMA MxNxK into group gid
WGMMA_COMMIT = "WGMMA_COMMIT"
WGMMA_WAIT = "WGMMA_WAIT"
BAR_ARRIVE = "BAR_ARRIVE"          # named barrier non-blocking signal
BAR_WAIT = "BAR_WAIT"              # block until >=k arrives
BUBBLES = "BUBBLES"                # CUDA-core work (softmax etc.)

# Well-known operand values shared by the trace generators and engine-side
# tooling.  Point-to-point tokens (e.g. "Q tile ready") use mbarrier sids
# allocated upward from Q_READY_SID, far above the ring-buffer stage sids
# (allocated upward from 0), so the two namespaces cannot collide; epilogue
# TMA store groups use EPILOGUE_GID, far above any WGMMA commit-group id.
Q_READY_SID = 98                   # first point-to-point token sid
EPILOGUE_GID = 99                  # epilogue TMA store commit group


@dataclass(frozen=True)
class TensorMap:
    """cuTensorMapEncodeTiled analogue: enough metadata for hardware address
    generation of a box (tile) anywhere in a strided tensor."""
    map_id: int
    base: int                      # byte address
    dims: Tuple[int, ...]          # logical tensor dims (row-major outer..inner)
    strides: Tuple[int, ...]       # byte strides per dim
    box: Tuple[int, ...]           # tile shape in elements
    esz: int                       # element size in bytes

    def tile_lines(self, origin: Tuple[int, ...], line_bytes: int,
                   dedup: bool = True):
        """Generate the cache-line addresses touched by the tile at
        ``origin``. With dedup=False, address generation is per *element*
        ("If we generate requests for each element, many duplicate requests
        will be generated" — §5.4): every element emits a request for its
        containing line (ablation: 'No line deduplication', paper Table 5)."""
        # innermost dim assumed contiguous (stride == esz)
        inner = self.box[-1] * self.esz
        lines: list = []
        # fold the outer dims into a flat row-major list of row base
        # addresses (outer index varies slowest — same depth-first order
        # as the recursive formulation this replaces)
        addrs = [self.base + origin[-1] * self.esz]
        for dim in range(len(self.box) - 1):
            o = origin[dim]
            s = self.strides[dim]
            addrs = [a + (o + i) * s
                     for a in addrs for i in range(self.box[dim])]
        if dedup:
            seen: set = set()
            add = seen.add
            ap = lines.append
            if inner % line_bytes == 0 and self.base % line_bytes == 0:
                # aligned rows: each row is exactly inner//line_bytes whole
                # lines starting at the row address (no per-line rounding)
                nl = inner // line_bytes
                for addr in addrs:
                    if addr % line_bytes:
                        a = addr - addr % line_bytes
                        end = addr + inner
                        while a < end:
                            if a not in seen:
                                add(a)
                                ap(a)
                            a += line_bytes
                    else:
                        for k in range(nl):
                            a = addr + k * line_bytes
                            if a not in seen:
                                add(a)
                                ap(a)
            else:
                for addr in addrs:
                    end = addr + inner
                    a = addr - addr % line_bytes
                    while a < end:
                        if a not in seen:
                            add(a)
                            ap(a)
                        a += line_bytes
        else:
            esz = self.esz
            ap = lines.append
            for addr in addrs:
                for e in range(self.box[-1]):
                    a = addr + e * esz
                    ap(a - a % line_bytes)
        return lines


@dataclass(frozen=True)
class Instr:
    op: str
    # generic operand fields (interpretation depends on op)
    sid: int = -1
    gid: int = -1
    bid: int = -1
    n: int = 0                      # WGMMA_WAIT/TMA_WAIT N; BAR_WAIT k
    m: int = 0                      # WGMMA M
    k: int = 0                      # WGMMA K
    cycles: int = 0                 # BUBBLES
    map_id: int = -1                # TMA ops
    origin: Tuple[int, ...] = ()    # TMA tile origin
    bulk: bool = False              # non-tensor bulk copy: skips the
                                    # descriptor-cache/TensorMap setup (Fig. 2)
    tag: str = ""                   # debug label (e.g. "K", "V", "QK", "PV")
