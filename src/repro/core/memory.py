"""Sim-FA memory hierarchy: LRC coalescer -> sliced L2 -> DRAM channels.

Models the timing-visible structures of the paper's §4.3/§5.4:
  * L2 Request Coalescer (LRC): merges duplicate in-flight line requests
    across each SM pair before they reach L2 (Table 5: no-LRC ablation).
  * 80-slice L2, XOR hash ``slice = (line ^ (line >> 5)) % N`` (Table 5:
    oversimplified-hash ablation uses the low bits instead).
  * per-slice MSHRs (merge misses to the same line; stall when full),
    near/far partition latency, write-back/write-allocate, alloc-on-fill.
  * RemoteCopy proxy: far-partition hits probabilistically insert a shadow
    line into the near partition, competing for capacity (paper Fig. 3).
  * DRAM: per-channel queues at HBM aggregate bandwidth + fixed latency
    (bandwidth/latency model in lieu of Ramulator; DESIGN.md §8).

All requests are 128B lines. Completion is callback-based: the engine hands
``(line_addr, sm_id, callback)``; the callback fires at absorb time.
"""
from __future__ import annotations

import heapq
import random
from collections import OrderedDict, defaultdict, deque
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.machine import GPUMachine


class EventQueue:
    """Shared simulation event queue, bucketed by cycle.

    Events land in per-cycle lists with a heap holding one entry per
    *distinct* pending cycle, so the common case — many completions at the
    same cycle — costs a list append instead of a heap sift.  Same-cycle
    events fire in push order, which is exactly the ``(cycle, seq)`` order
    the previous flat heap produced; callbacks that push new events at the
    cycle currently draining are picked up within the same drain (the flat
    heap's ``<= cycle`` semantics).

    ``wake_at`` is the coalesced timer-wake primitive: no matter how many
    threads park on the same ``(cycle, waker)``, exactly one event fires —
    ``waker(cycle)`` — letting a scheduler park whole groups of
    ``busy_until`` threads on one targeted timer instead of one broadcast
    wake per thread.
    """

    def __init__(self):
        self._h: List[int] = []          # pending cycles (one entry each)
        self._buckets: Dict[int, list] = {}
        self.now = 0            # cycle of the event currently executing
        self.popped = 0         # total events executed (sim throughput stat)
        self._wakes: set = set()         # live (cycle, waker) timer keys

    def push(self, cycle: int, fn: Callable, *args):
        b = self._buckets.get(cycle)
        if b is None:
            self._buckets[cycle] = b = []
            heapq.heappush(self._h, cycle)
        b.append((fn, args))

    def wake_at(self, cycle: int, waker: Callable):
        """Schedule ``waker(cycle)`` at ``cycle``, coalescing duplicates:
        repeated requests for the same (cycle, waker) are one event."""
        key = (cycle, waker)
        if key in self._wakes:
            return
        self._wakes.add(key)
        self.push(cycle, self._fire_wake, key)

    def _fire_wake(self, key):
        self._wakes.discard(key)
        key[1](key[0])

    def pop_ready(self, cycle: int):
        h = self._h
        buckets = self._buckets
        while h and h[0] <= cycle:
            t = heapq.heappop(h)
            self.now = t
            lst = buckets[t]
            i = 0
            while i < len(lst):     # callbacks may append to this bucket
                fn, args = lst[i]
                i += 1
                fn(*args)
            self.popped += i
            del buckets[t]

    def next_cycle(self) -> Optional[int]:
        return self._h[0] if self._h else None

    def __len__(self):
        return sum(len(b) for b in self._buckets.values())


class DRAM:
    """Per-channel queueing bandwidth/latency model."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, scale: float = 1.0):
        self.cfg = cfg
        self.evq = evq
        n = max(1, int(round(cfg.dram_channels * scale)))
        self.channels = n
        self.free_at = [0] * n          # next cycle each channel can start
        self.service = cfg.dram_line_service_cycles
        self.bytes_served = 0
        self.busy_cycles = 0.0          # channel-occupied cycles (observable
                                        # only: feeds obs counter timelines)
        self.faults = None              # repro.faults.FaultSession hook

    def access(self, cycle: int, line: int, cb: Callable):
        ch = (line // self.cfg.line_bytes) % self.channels
        start = max(cycle, self.free_at[ch])
        self.free_at[ch] = start + self.service
        self.bytes_served += self.cfg.line_bytes
        self.busy_cycles += self.service
        fl = self.faults
        lat = (self.cfg.dram_latency if fl is None
               else self.cfg.dram_latency + fl.dram_extra())
        self.evq.push(int(start + self.service + lat), cb)


class L2Slice:
    """One L2 slice: LRU tags + MSHRs + near/far latency."""

    def __init__(self, sid: int, cfg: GPUMachine, dram: DRAM, evq: EventQueue,
                 lines_capacity: int):
        self.sid = sid
        self.cfg = cfg
        self.dram = dram
        self.evq = evq
        self.capacity = max(16, lines_capacity)
        self.tags: "OrderedDict[int, bool]" = OrderedDict()  # line -> dirty
        self.mshr: Dict[int, List[Callable]] = {}
        self.stalled: deque = deque()   # requests waiting for an MSHR
        # stats
        self.hits = 0
        self.misses = 0
        self.mshr_merges = 0
        self.rc_inserts = 0
        self.mshr_peak = 0              # high-water outstanding misses
                                        # (observable only: MSHR pressure)
        self.faults = None              # repro.faults.FaultSession hook

    @property
    def occupancy(self) -> float:
        return len(self.tags) / self.capacity

    def _insert(self, line: int, dirty: bool = False):
        if line in self.tags:
            self.tags.move_to_end(line)
            return
        self.tags[line] = dirty
        if len(self.tags) > self.capacity:
            self.tags.popitem(last=False)   # LRU evict (write-back not timed)

    def access(self, cycle: int, line: int, far: bool, cb: Callable,
               write: bool = False):
        # a full MSHR pool stalls the whole request path for this slice
        # (head-of-line blocking): hits behind the stall wait too (§4.3,
        # "once it fills, no new misses can be issued to DRAM")
        if self.stalled:
            self.stalled.append((line, far, cb, write))
            return
        self._access(cycle, line, far, cb, write)

    def _access(self, cycle: int, line: int, far: bool, cb: Callable,
                write: bool = False):
        lat = self.cfg.l2_far_latency if far else self.cfg.l2_near_latency
        fl = self.faults
        if fl is not None:
            lat += fl.l2_extra(far)
        if line in self.tags:
            self.hits += 1
            self.tags.move_to_end(line)
            if write:
                self.tags[line] = True
            self.evq.push(cycle + lat, cb)
            return
        # miss
        if line in self.mshr:               # MSHR hit: merge
            self.mshr_merges += 1
            self.mshr[line].append(cb)
            return
        if len(self.mshr) >= self.cfg.l2_mshr_per_slice:
            self.stalled.append((line, far, cb, write))
            return
        self.misses += 1
        self.mshr[line] = [cb]
        if len(self.mshr) > self.mshr_peak:
            self.mshr_peak = len(self.mshr)

        def fill():
            self._insert(line, dirty=write)      # alloc-on-fill
            waiters = self.mshr.pop(line, [])
            for w in waiters:
                w()
            # drain the stalled request path now that an MSHR freed up
            while self.stalled and len(self.mshr) < self.cfg.l2_mshr_per_slice:
                l2, f2, c2, w2 = self.stalled.popleft()
                self._access(self.evq.now, l2, f2, c2, w2)

        self.dram.access(cycle + lat, line, fill)


class L2Cache:
    """Sliced L2 with XOR hash, two partitions, and the RemoteCopy proxy."""

    def __init__(self, cfg: GPUMachine, dram: DRAM, evq: EventQueue,
                 scale: float = 1.0, seed: int = 0):
        self.cfg = cfg
        self.evq = evq
        n = max(2, int(round(cfg.l2_slices * scale)))
        per_slice_lines = int(cfg.l2_bytes * scale) // cfg.line_bytes // n
        self.slices = [L2Slice(i, cfg, dram, evq, per_slice_lines)
                       for i in range(n)]
        self.n = n
        self.rng = random.Random(seed)
        self.requests = 0
        self.faults = None              # repro.faults.FaultSession hook

    def slice_of(self, line_addr: int) -> int:
        line = line_addr // self.cfg.line_bytes
        if self.cfg.xor_hash:
            return (line ^ (line >> 5)) % self.n
        return line % self.n           # ablation: low bits only

    def access(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
               write: bool = False):
        self.requests += 1
        s = self.slice_of(line_addr)
        sl = self.slices[s]
        # partition: slices [0, n/2) near SMs [0, num_sms/2), else far
        near_part = 0 if sm_id < self.cfg.num_sms // 2 else 1
        slice_part = 0 if s < self.n // 2 else 1
        far = near_part != slice_part

        if far and self.cfg.remote_copy:
            # behavioral RemoteCopy proxy (§4.3): far lines get mirrored into
            # the requester-side twin slice. Mirrors (a) serve later reads at
            # near latency — the L2-hit floor — and (b) compete with regular
            # lines for capacity, which halves the effective L2 for shared
            # working sets: the 25 MB boundary of §6.2.2 and the 25-50 MB
            # fluctuating transition window of Fig. 3.
            mirror = self.slices[(s + self.n // 2) % self.n]
            if line_addr in mirror.tags:
                if write:
                    mirror.tags.pop(line_addr, None)   # keep mirrors clean
                else:
                    mirror.hits += 1
                    mirror.tags.move_to_end(line_addr)
                    fl = self.faults
                    lat = (self.cfg.l2_near_latency if fl is None
                           else self.cfg.l2_near_latency + fl.l2_extra(False))
                    self.evq.push(cycle + lat, cb)
                    return
            elif (not write and line_addr in sl.tags
                  and mirror.occupancy < self.cfg.rc_occupancy_threshold
                  and self.rng.random() < self.cfg.rc_max_prob):
                mirror._insert(line_addr)
                mirror.rc_inserts += 1
        sl.access(cycle, line_addr, far, cb, write)

    # stats -----------------------------------------------------------------
    def stats(self):
        agg = defaultdict(int)
        for sl in self.slices:
            agg["hits"] += sl.hits
            agg["misses"] += sl.misses
            agg["mshr_merges"] += sl.mshr_merges
            agg["rc_inserts"] += sl.rc_inserts
            if sl.mshr_peak > agg["mshr_peak"]:
                agg["mshr_peak"] = sl.mshr_peak
        agg["requests"] = self.requests
        return dict(agg)


class LRC:
    """L2 Request Coalescer: merges duplicate outstanding line requests from
    an SM pair (paper §5.4). Without it every CTA's TMA traffic reaches L2."""

    def __init__(self, cfg: GPUMachine, l2: L2Cache):
        self.cfg = cfg
        self.l2 = l2
        # key -> single waiter callable, promoted to a list on first merge
        # (the single-waiter case is ~all of them; skipping the list saves
        # an allocation per line on the hot path)
        self.pending: Dict[Tuple[int, int], object] = {}
        self.merged = 0
        # line -> (home slice, home partition, mirror slice), lazily built:
        # the slice hash and partition of a line never change, so the hot
        # path pays one dict hit instead of recomputing hash + partition
        self._meta: Dict[int, tuple] = {}
        self.faults = None              # repro.faults.FaultSession hook
        # machine constants hoisted off cfg: read once per request, not via
        # an attribute chain
        self._enabled = cfg.lrc_enabled
        self._near = cfg.l2_near_latency
        self._far = cfg.l2_far_latency
        self._rc = cfg.remote_copy
        self._rc_thresh = cfg.rc_occupancy_threshold
        self._rc_prob = cfg.rc_max_prob
        self._half_sms = cfg.num_sms // 2

    def request(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
                write: bool = False):
        self.request_many(cycle, (line_addr,), sm_id, cb, write)

    def _line_meta(self, line_addr: int):
        l2 = self.l2
        s = l2.slice_of(line_addr)
        m = (l2.slices[s], 0 if s < l2.n // 2 else 1,
             l2.slices[(s + l2.n // 2) % l2.n])
        self._meta[line_addr] = m
        return m

    def request_many(self, cycle: int, lines, sm_id: int, cb: Callable,
                     write: bool = False):
        """Batch entry point: one call per TMA issue cycle, one shared ``cb``
        invoked once per completed line (the engine's per-job counter).

        The read path inlines the L2 hit handling (including the RemoteCopy
        mirror probe, preserving the exact RNG draw sequence) so the
        steady-state K/V re-stream — an L2 hit per line — costs a couple of
        dict probes and a bucket append instead of the full
        ``L2Cache.access`` call chain.  Misses, MSHR pressure and stalled
        slices fall back to the unfused slow path."""
        if not self._enabled or write:
            l2 = self.l2
            for line_addr in lines:
                l2.access(cycle, line_addr, sm_id, cb, write)
            return
        l2 = self.l2
        pending = self.pending
        meta = self._meta
        evq = l2.evq
        fanout = self._fanout
        pair = sm_id // 2
        req_part = 0 if sm_id < self._half_sms else 1
        rc = self._rc
        near_lat = self._near
        far_lat = self._far
        rc_thresh = self._rc_thresh
        rc_prob = self._rc_prob
        rng = l2.rng.random
        fl = self.faults       # fused L2-hit paths bypass L2Slice._access,
                               # so the jitter hook is applied inline here
        for line_addr in lines:
            key = (pair, line_addr)
            waiters = pending.get(key)
            if waiters is not None:
                self.merged += 1
                if waiters.__class__ is list:
                    waiters.append(cb)
                else:
                    pending[key] = [waiters, cb]
                continue
            pending[key] = cb
            l2.requests += 1
            m = meta.get(line_addr)
            if m is None:
                m = self._line_meta(line_addr)
            sl, home_part, mirror = m
            if home_part == req_part:                       # near access
                if not sl.stalled and line_addr in sl.tags:
                    sl.hits += 1
                    sl.tags.move_to_end(line_addr)
                    lat = (near_lat if fl is None
                           else near_lat + fl.l2_extra(False))
                    evq.push(cycle + lat, fanout, key)
                    continue
                sl.access(cycle, line_addr, False, partial(fanout, key))
                continue
            if rc:                     # far read: RemoteCopy proxy (§4.3)
                mtags = mirror.tags
                if line_addr in mtags:
                    mirror.hits += 1
                    mtags.move_to_end(line_addr)
                    lat = (near_lat if fl is None
                           else near_lat + fl.l2_extra(False))
                    evq.push(cycle + lat, fanout, key)
                    continue
                if (line_addr in sl.tags
                        and mirror.occupancy < rc_thresh
                        and rng() < rc_prob):
                    mirror._insert(line_addr)
                    mirror.rc_inserts += 1
            if not sl.stalled and line_addr in sl.tags:
                sl.hits += 1
                sl.tags.move_to_end(line_addr)
                lat = (far_lat if fl is None
                       else far_lat + fl.l2_extra(True))
                evq.push(cycle + lat, fanout, key)
                continue
            sl.access(cycle, line_addr, True, partial(fanout, key))

    def request_one(self, cycle: int, line_addr: int, sm_id: int,
                    cb: Callable, write: bool = False):
        """Single-line fast entry — the TMA engines' targeted-refill path
        (one replacement line per completed line, see engine.TMAEngine)."""
        if not self._enabled or write:
            self.l2.access(cycle, line_addr, sm_id, cb, write)
            return
        key = (sm_id // 2, line_addr)
        pending = self.pending
        waiters = pending.get(key)
        if waiters is not None:
            self.merged += 1
            if waiters.__class__ is list:
                waiters.append(cb)
            else:
                pending[key] = [waiters, cb]
            return
        pending[key] = cb
        l2 = self.l2
        l2.requests += 1
        m = self._meta.get(line_addr)
        if m is None:
            m = self._line_meta(line_addr)
        sl, home_part, mirror = m
        fanout = self._fanout
        fl = self.faults
        if home_part == (0 if sm_id < self._half_sms else 1):
            if not sl.stalled and line_addr in sl.tags:
                sl.hits += 1
                sl.tags.move_to_end(line_addr)
                lat = (self._near if fl is None
                       else self._near + fl.l2_extra(False))
                l2.evq.push(cycle + lat, fanout, key)
                return
            sl.access(cycle, line_addr, False, partial(fanout, key))
            return
        if self._rc:
            mtags = mirror.tags
            if line_addr in mtags:
                mirror.hits += 1
                mtags.move_to_end(line_addr)
                lat = (self._near if fl is None
                       else self._near + fl.l2_extra(False))
                l2.evq.push(cycle + lat, fanout, key)
                return
            if (line_addr in sl.tags
                    and mirror.occupancy < self._rc_thresh
                    and l2.rng.random() < self._rc_prob):
                mirror._insert(line_addr)
                mirror.rc_inserts += 1
        if not sl.stalled and line_addr in sl.tags:
            sl.hits += 1
            sl.tags.move_to_end(line_addr)
            lat = (self._far if fl is None
                   else self._far + fl.l2_extra(True))
            l2.evq.push(cycle + lat, fanout, key)
            return
        sl.access(cycle, line_addr, True, partial(fanout, key))

    def _fanout(self, key):
        w = self.pending.pop(key, None)
        if w is None:
            return
        if w.__class__ is list:
            for f in w:
                f()
        else:
            w()


class DirectHBM:
    """TPU-mode memory front end: no shared L2 between cores and HBM —
    requests go straight to the DRAM channel model plus a fixed latency."""

    def __init__(self, cfg: GPUMachine, dram: DRAM, evq: EventQueue):
        self.cfg = cfg
        self.dram = dram
        self.evq = evq
        self.merged = 0
        self.requests = 0

    def request(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
                write: bool = False):
        self.requests += 1
        self.dram.access(cycle, line_addr, cb)

    request_one = request

    def request_many(self, cycle: int, lines, sm_id: int, cb: Callable,
                     write: bool = False):
        self.requests += len(lines)
        dram = self.dram
        for line_addr in lines:
            dram.access(cycle, line_addr, cb)

    def stats(self):
        return {"requests": self.requests, "hits": 0, "misses": self.requests,
                "mshr_merges": 0, "rc_inserts": 0, "mshr_peak": 0}


class TileMemory:
    """Tile-granular memory front end (``Engine(mem_fidelity="tile")``).

    Collapses a TMA tile's ``tile_lines`` per-line cache events into ONE
    bulk transaction: residency, slice-partition distribution, merge
    windows and DRAM channel occupancy are charged at tile granularity and
    the whole tile completes with a single EventQueue callback, instead of
    per-line LRC/MSHR/refill bookkeeping (docs/fidelity.md).

    Byte-exact vs. the line-exact hierarchy (asserted by
    ``tests/test_engine_equiv.py`` and ``benchmarks/bench_fidelity.py``):
    ``dram_bytes``, ``tma_lines``, and L2 ``misses`` — these are structural
    (the set of first-touched lines), not timing-dependent.  Approximated:
    request/merge *split* (line-exact merge windows depend on sub-cycle
    event interleaving — see docs/fidelity.md for why byte-identical
    post-coalescer traffic is unattainable at tile granularity) and all
    latencies, which come from a streaming service model (issue rate +
    in-flight cap + blended near/far latency) over the same machine
    constants, validated to the documented cycle-error bound.

    State is O(tiles): a tile-granular LRU with per-tile slice-partition
    counts, lazily-expired fill/merge windows (no cleanup events), and the
    shared per-channel DRAM ``free_at`` model charged in bulk.
    """

    def __init__(self, cfg: GPUMachine, dram: DRAM, evq: EventQueue,
                 scale: float = 1.0, seed: int = 0):
        self.cfg = cfg
        self.dram = dram
        self.evq = evq
        self.rng = random.Random(seed)   # RC mirror draws (tile-granular)
        n = max(2, int(round(cfg.l2_slices * scale)))
        self._nsl = n
        self._half_slice = n // 2
        self.capacity = max(16, int(cfg.l2_bytes * scale) // cfg.line_bytes)
        # tile key -> [distinct_line_list, part0_lines, part1_lines, m0, m1]
        # (m0/m1: RemoteCopy mirror present for requesters in partition 0/1)
        self.tiles: "OrderedDict[tuple, list]" = OrderedDict()
        # line -> number of resident tiles containing it.  Tiles of different
        # tensor maps can OVERLAP (unclamped boxes spill across region
        # boundaries), so misses/dram_bytes must be counted per line, not
        # per tile — this is what keeps them byte-identical to line-exact.
        self.line_ref: Dict[int, int] = {}
        self.mirror_lines = 0           # RC mirror capacity pressure
        self.fill_done: Dict[tuple, Tuple[int, int]] = {}  # key -> (t, lines)
        # (pair, key) -> merge window (issue start, stream end)
        self.pending: Dict[tuple, Tuple[int, int]] = {}
        # per-SM TMA port: aggregate issue-slot occupancy (lines_per_cycle)
        self.port_free: Dict[int, int] = {}
        # stats: same schema as L2Cache.stats() + LRC.merged, all in lines
        self.hits = 0
        self.misses = 0
        self.mshr_merges = 0
        self.rc_inserts = 0
        self.mshr_peak = 0
        self.requests = 0
        self.merged = 0
        self.faults = None              # repro.faults.FaultSession hook
        # hot machine constants
        self._lb = cfg.line_bytes
        self._xor = cfg.xor_hash
        self._lpc = cfg.tma_lines_per_cycle
        self._cap = cfg.tma_max_inflight_lines
        self._near = cfg.l2_near_latency
        self._far = cfg.l2_far_latency
        self._dram_lat = cfg.dram_latency
        self._half_sms = cfg.num_sms // 2
        self._lrc_on = cfg.lrc_enabled
        self._dedup = cfg.tma_dedup
        self._rc = cfg.remote_copy
        self._rc_thresh = cfg.rc_occupancy_threshold
        self._rc_prob = cfg.rc_max_prob

    # ------------------------------------------------------------------
    def _stream(self, base: int, n: int, lam: int) -> int:
        """Completion cycle of an n-line stream starting at ``base``: issue
        at ``tma_lines_per_cycle``, at most ``tma_max_inflight_lines``
        outstanding at per-line latency ``lam`` (Little's-law throughput
        when the cap binds), plus the last line's latency."""
        tail = (n - 1) // self._lpc
        c = self._cap
        if n > c:
            alt = (n - c) * lam // c
            if alt > tail:
                tail = alt
        return base + tail + lam

    def _part_counts(self, lines) -> Tuple[int, int]:
        """Count distinct lines homed in each L2 partition (XOR slice hash,
        slices [0, n/2) = partition 0) — computed once per resident tile."""
        n = self._nsl
        half = self._half_slice
        lb = self._lb
        p0 = 0
        if self._xor:
            for la in lines:
                ln = la // lb
                if (ln ^ (ln >> 5)) % n < half:
                    p0 += 1
        else:
            for la in lines:
                if (la // lb) % n < half:
                    p0 += 1
        return p0, len(lines) - p0

    @property
    def resident_lines(self) -> int:
        return len(self.line_ref) + self.mirror_lines

    def _evict(self, cycle: int):
        tiles = self.tiles
        fd = self.fill_done
        ref = self.line_ref
        cap = self.capacity
        scanned = 0
        while len(ref) + self.mirror_lines > cap and scanned < len(tiles):
            key = next(iter(tiles))
            w = fd.get(key)
            if w is not None and w[0] > cycle:
                # still filling: its lines are MSHR-held in line-exact mode,
                # so eviction can't reach them — skip (keeps dram_bytes exact)
                tiles.move_to_end(key)
                scanned += 1
                continue
            ent = tiles.pop(key)
            fd.pop(key, None)
            for la in ent[0]:
                c = ref[la]
                if c == 1:
                    del ref[la]
                else:
                    ref[la] = c - 1
            if ent[3]:
                self.mirror_lines -= ent[2]
            if ent[4]:
                self.mirror_lines -= ent[1]

    # ------------------------------------------------------------------
    def transact(self, cycle: int, lines, sm_id: int, write: bool) -> int:
        """Charge one TMA tile as a single bulk transaction; returns the
        cycle the whole tile completes (always > ``cycle``)."""
        n = len(lines)
        key = (lines[0], lines[-1], n)
        fl = self.faults
        port = self.port_free
        base = port.get(sm_id, 0)
        if base < cycle:
            base = cycle
        # the tile consumes n issue slots of this SM's TMA port (the
        # work-conserving view of the per-cycle line budget)
        port[sm_id] = base + (n + self._lpc - 1) // self._lpc

        # Coalescer merge window: a pair-mate streaming the same tile while
        # the original's stream is still in flight merges whole.  Line-exact
        # merging is per *line* (only lines still pending merge; the rest
        # re-request as hits), but both streams issue at the same per-cycle
        # rate, so merged completions track the original's and the race
        # offset stays constant — whole-window all-merge is the closest
        # tile-granular analogue.  The residual split error is measured per
        # cell by benchmarks/bench_fidelity.py and documented in
        # docs/fidelity.md (largest on tiny launches, where a handful of
        # mis-merged tiles is a big fraction of a small request count).
        if self._lrc_on and not write:
            pkey = (sm_id // 2, key)
            prev = self.pending.get(pkey)
            if prev is not None and prev[1] > cycle:
                self.merged += n
                t = self._stream(base, n, 0)
                if t < prev[1]:
                    t = prev[1]
                return t
        else:
            pkey = None

        nd = n if self._dedup else len(set(lines))
        if self._lrc_on and not write:
            self.requests += nd
            self.merged += n - nd       # intra-tile duplicates coalesce
        else:
            self.requests += n
        part = 0 if sm_id < self._half_sms else 1

        ent = self.tiles.get(key)
        filling = None
        if ent is not None:
            w = self.fill_done.get(key)
            if w is not None:
                if w[0] > cycle:
                    filling = w[0]
                else:
                    del self.fill_done[key]
        if ent is None:
            # first touch (or re-touch after eviction): every line not
            # already resident via an overlapping tile misses — bulk-charge
            # the DRAM channels line by line (channel interleave + queueing
            # preserved), one latency draw per tile
            if self._dedup:
                dl = lines
            else:
                dl = list(dict.fromkeys(lines))
            ref = self.line_ref
            dram = self.dram
            free = dram.free_at
            nch = dram.channels
            svc = dram.service
            lb = self._lb
            t_fill = 0
            nm = 0
            for la in dl:
                c = ref.get(la)
                if c:
                    ref[la] = c + 1
                    continue
                ref[la] = 1
                nm += 1
                ch = (la // lb) % nch
                s = free[ch]
                if s < cycle:
                    s = cycle
                e = s + svc
                free[ch] = e
                if e > t_fill:
                    t_fill = e
            self.misses += nm
            self.hits += nd - nm
            dram.bytes_served += nm * lb
            dram.busy_cycles += nm * svc
            p0, p1 = self._part_counts(dl)
            ent = [dl, p0, p1, 0, 0]
            self.tiles[key] = ent
            self._evict(cycle)
            far = p1 if part == 0 else p0
            lam = (self._near * (nd - far) + self._far * far) // nd
            if fl is not None:
                lam += fl.l2_extra(far > 0)
            if nm:
                # outstanding fill lines across live windows = MSHR pressure
                out = nm
                fd = self.fill_done
                for k in list(fd):
                    w = fd[k]
                    if w[0] <= cycle:
                        del fd[k]
                    else:
                        out += w[1]
                if out > self.mshr_peak:
                    self.mshr_peak = out
                dlat = self._dram_lat if fl is None else \
                    self._dram_lat + fl.dram_extra()
                t_fill += dlat + lam
                fd[key] = (t_fill, nm)
                # per-line slot time blends the missed fraction's DRAM trip
                lam_w = lam + dlat * nm // nd
                t = self._stream(base, nd, lam_w)
                if t < t_fill:
                    t = t_fill
            else:
                lam_w = lam
                t = self._stream(base, nd, lam)
        elif filling is not None:
            # tile fill already in flight from another SM pair: every line
            # merges into the outstanding MSHRs and lands with the fill
            self.mshr_merges += nd
            self.tiles.move_to_end(key)
            lam = self._near if fl is None else self._near + fl.l2_extra(False)
            lam_w = lam
            t = self._stream(base, nd, lam)
            if t < filling + lam:
                t = filling + lam
        else:
            # resident tile: streamed L2 hits at blended near/far latency
            self.hits += nd
            self.tiles.move_to_end(key)
            mirrored = ent[4] if part else ent[3]
            far = 0 if mirrored else (ent[2] if part == 0 else ent[1])
            lam = (self._near * (nd - far) + self._far * far) // nd
            if fl is not None:
                lam += fl.l2_extra(far > 0)
            if (far and not write and self._rc
                    and self.resident_lines < self.capacity * self._rc_thresh
                    and self.rng.random() < self._rc_prob):
                # RemoteCopy proxy at tile granularity: mirror the far half
                # into the requester partition; helps *subsequent* accesses
                # and competes for capacity like line-exact mirrors do
                if part:
                    ent[4] = 1
                else:
                    ent[3] = 1
                self.rc_inserts += far
                self.mirror_lines += far
                self._evict(cycle)
            lam_w = lam
            t = self._stream(base, nd, lam)
        if pkey is not None:
            # completions span [first line's landing, stream end]
            self.pending[pkey] = (base + lam_w, t)
            if len(self.pending) > 4096:    # lazy sweep of expired windows
                self.pending = {k: v for k, v in self.pending.items()
                                if v[1] > cycle}
        return t

    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "mshr_merges": self.mshr_merges,
                "rc_inserts": self.rc_inserts, "mshr_peak": self.mshr_peak,
                "requests": self.requests}


def build_memory(cfg: GPUMachine, evq: EventQueue, scale: float = 1.0,
                 seed: int = 0, direct: bool = False, tile: bool = False):
    dram = DRAM(cfg, evq, scale)
    if direct:
        if tile:
            raise ValueError("mem_fidelity='tile' models the sliced-L2 "
                             "path; direct HBM has no per-line cache events "
                             "to collapse")
        front = DirectHBM(cfg, dram, evq)
        return front, front, dram
    if tile:
        if not cfg.lrc_enabled:
            raise ValueError(
                "mem_fidelity='tile' requires the L2 request coalescer "
                "(lrc_enabled): the no-LRC ablation studies per-line "
                "request flooding and slice contention, which only exist "
                "at line-exact fidelity")
        front = TileMemory(cfg, dram, evq, scale, seed)
        return front, front, dram
    l2 = L2Cache(cfg, dram, evq, scale, seed)
    lrc = LRC(cfg, l2)
    return lrc, l2, dram
