"""Sim-FA memory hierarchy: LRC coalescer -> sliced L2 -> DRAM channels.

Models the timing-visible structures of the paper's §4.3/§5.4:
  * L2 Request Coalescer (LRC): merges duplicate in-flight line requests
    across each SM pair before they reach L2 (Table 5: no-LRC ablation).
  * 80-slice L2, XOR hash ``slice = (line ^ (line >> 5)) % N`` (Table 5:
    oversimplified-hash ablation uses the low bits instead).
  * per-slice MSHRs (merge misses to the same line; stall when full),
    near/far partition latency, write-back/write-allocate, alloc-on-fill.
  * RemoteCopy proxy: far-partition hits probabilistically insert a shadow
    line into the near partition, competing for capacity (paper Fig. 3).
  * DRAM: per-channel queues at HBM aggregate bandwidth + fixed latency
    (bandwidth/latency model in lieu of Ramulator; DESIGN.md §8).

All requests are 128B lines. Completion is callback-based: the engine hands
``(line_addr, sm_id, callback)``; the callback fires at absorb time.
"""
from __future__ import annotations

import heapq
import random
from collections import OrderedDict, defaultdict, deque
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.machine import GPUMachine


class EventQueue:
    """Shared simulation event heap: (cycle, seq, fn, args)."""

    def __init__(self):
        self._h: List = []
        self._seq = 0
        self.now = 0            # cycle of the event currently executing
        self.popped = 0         # total events executed (sim throughput stat)

    def push(self, cycle: int, fn: Callable, *args):
        heapq.heappush(self._h, (cycle, self._seq, fn, args))
        self._seq += 1

    def pop_ready(self, cycle: int):
        h = self._h
        while h and h[0][0] <= cycle:
            t, _, fn, args = heapq.heappop(h)
            self.now = t
            self.popped += 1
            fn(*args)

    def next_cycle(self) -> Optional[int]:
        return self._h[0][0] if self._h else None

    def __len__(self):
        return len(self._h)


class DRAM:
    """Per-channel queueing bandwidth/latency model."""

    def __init__(self, cfg: GPUMachine, evq: EventQueue, scale: float = 1.0):
        self.cfg = cfg
        self.evq = evq
        n = max(1, int(round(cfg.dram_channels * scale)))
        self.channels = n
        self.free_at = [0] * n          # next cycle each channel can start
        self.service = cfg.dram_line_service_cycles
        self.bytes_served = 0

    def access(self, cycle: int, line: int, cb: Callable):
        ch = (line // self.cfg.line_bytes) % self.channels
        start = max(cycle, self.free_at[ch])
        self.free_at[ch] = start + self.service
        self.bytes_served += self.cfg.line_bytes
        self.evq.push(int(start + self.service + self.cfg.dram_latency), cb)


class L2Slice:
    """One L2 slice: LRU tags + MSHRs + near/far latency."""

    def __init__(self, sid: int, cfg: GPUMachine, dram: DRAM, evq: EventQueue,
                 lines_capacity: int):
        self.sid = sid
        self.cfg = cfg
        self.dram = dram
        self.evq = evq
        self.capacity = max(16, lines_capacity)
        self.tags: "OrderedDict[int, bool]" = OrderedDict()  # line -> dirty
        self.mshr: Dict[int, List[Callable]] = {}
        self.stalled: deque = deque()   # requests waiting for an MSHR
        # stats
        self.hits = 0
        self.misses = 0
        self.mshr_merges = 0
        self.rc_inserts = 0

    @property
    def occupancy(self) -> float:
        return len(self.tags) / self.capacity

    def _insert(self, line: int, dirty: bool = False):
        if line in self.tags:
            self.tags.move_to_end(line)
            return
        self.tags[line] = dirty
        if len(self.tags) > self.capacity:
            self.tags.popitem(last=False)   # LRU evict (write-back not timed)

    def access(self, cycle: int, line: int, far: bool, cb: Callable,
               write: bool = False):
        # a full MSHR pool stalls the whole request path for this slice
        # (head-of-line blocking): hits behind the stall wait too (§4.3,
        # "once it fills, no new misses can be issued to DRAM")
        if self.stalled:
            self.stalled.append((line, far, cb, write))
            return
        self._access(cycle, line, far, cb, write)

    def _access(self, cycle: int, line: int, far: bool, cb: Callable,
                write: bool = False):
        lat = self.cfg.l2_far_latency if far else self.cfg.l2_near_latency
        if line in self.tags:
            self.hits += 1
            self.tags.move_to_end(line)
            if write:
                self.tags[line] = True
            self.evq.push(cycle + lat, cb)
            return
        # miss
        if line in self.mshr:               # MSHR hit: merge
            self.mshr_merges += 1
            self.mshr[line].append(cb)
            return
        if len(self.mshr) >= self.cfg.l2_mshr_per_slice:
            self.stalled.append((line, far, cb, write))
            return
        self.misses += 1
        self.mshr[line] = [cb]

        def fill():
            self._insert(line, dirty=write)      # alloc-on-fill
            waiters = self.mshr.pop(line, [])
            for w in waiters:
                w()
            # drain the stalled request path now that an MSHR freed up
            while self.stalled and len(self.mshr) < self.cfg.l2_mshr_per_slice:
                l2, f2, c2, w2 = self.stalled.popleft()
                self._access(self.evq.now, l2, f2, c2, w2)

        self.dram.access(cycle + lat, line, fill)


class L2Cache:
    """Sliced L2 with XOR hash, two partitions, and the RemoteCopy proxy."""

    def __init__(self, cfg: GPUMachine, dram: DRAM, evq: EventQueue,
                 scale: float = 1.0, seed: int = 0):
        self.cfg = cfg
        self.evq = evq
        n = max(2, int(round(cfg.l2_slices * scale)))
        per_slice_lines = int(cfg.l2_bytes * scale) // cfg.line_bytes // n
        self.slices = [L2Slice(i, cfg, dram, evq, per_slice_lines)
                       for i in range(n)]
        self.n = n
        self.rng = random.Random(seed)
        self.requests = 0

    def slice_of(self, line_addr: int) -> int:
        line = line_addr // self.cfg.line_bytes
        if self.cfg.xor_hash:
            return (line ^ (line >> 5)) % self.n
        return line % self.n           # ablation: low bits only

    def access(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
               write: bool = False):
        self.requests += 1
        s = self.slice_of(line_addr)
        sl = self.slices[s]
        # partition: slices [0, n/2) near SMs [0, num_sms/2), else far
        near_part = 0 if sm_id < self.cfg.num_sms // 2 else 1
        slice_part = 0 if s < self.n // 2 else 1
        far = near_part != slice_part

        if far and self.cfg.remote_copy:
            # behavioral RemoteCopy proxy (§4.3): far lines get mirrored into
            # the requester-side twin slice. Mirrors (a) serve later reads at
            # near latency — the L2-hit floor — and (b) compete with regular
            # lines for capacity, which halves the effective L2 for shared
            # working sets: the 25 MB boundary of §6.2.2 and the 25-50 MB
            # fluctuating transition window of Fig. 3.
            mirror = self.slices[(s + self.n // 2) % self.n]
            if line_addr in mirror.tags:
                if write:
                    mirror.tags.pop(line_addr, None)   # keep mirrors clean
                else:
                    mirror.hits += 1
                    mirror.tags.move_to_end(line_addr)
                    self.evq.push(cycle + self.cfg.l2_near_latency, cb)
                    return
            elif (not write and line_addr in sl.tags
                  and mirror.occupancy < self.cfg.rc_occupancy_threshold
                  and self.rng.random() < self.cfg.rc_max_prob):
                mirror._insert(line_addr)
                mirror.rc_inserts += 1
        sl.access(cycle, line_addr, far, cb, write)

    # stats -----------------------------------------------------------------
    def stats(self):
        agg = defaultdict(int)
        for sl in self.slices:
            agg["hits"] += sl.hits
            agg["misses"] += sl.misses
            agg["mshr_merges"] += sl.mshr_merges
            agg["rc_inserts"] += sl.rc_inserts
        agg["requests"] = self.requests
        return dict(agg)


class LRC:
    """L2 Request Coalescer: merges duplicate outstanding line requests from
    an SM pair (paper §5.4). Without it every CTA's TMA traffic reaches L2."""

    def __init__(self, cfg: GPUMachine, l2: L2Cache):
        self.cfg = cfg
        self.l2 = l2
        self.pending: Dict[Tuple[int, int], List[Callable]] = {}
        self.merged = 0

    def request(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
                write: bool = False):
        self.request_many(cycle, (line_addr,), sm_id, cb, write)

    def request_many(self, cycle: int, lines, sm_id: int, cb: Callable,
                     write: bool = False):
        """Batch entry point: one call per TMA issue cycle, one shared ``cb``
        invoked once per completed line (the engine's per-job counter)."""
        if not self.cfg.lrc_enabled or write:
            l2 = self.l2
            for line_addr in lines:
                l2.access(cycle, line_addr, sm_id, cb, write)
            return
        pending = self.pending
        pair = sm_id // 2
        for line_addr in lines:
            key = (pair, line_addr)
            waiters = pending.get(key)
            if waiters is not None:
                self.merged += 1
                waiters.append(cb)
                continue
            pending[key] = [cb]
            self.l2.access(cycle, line_addr, sm_id,
                           partial(self._fanout, key))

    def _fanout(self, key):
        for w in self.pending.pop(key, ()):
            w()


class DirectHBM:
    """TPU-mode memory front end: no shared L2 between cores and HBM —
    requests go straight to the DRAM channel model plus a fixed latency."""

    def __init__(self, cfg: GPUMachine, dram: DRAM, evq: EventQueue):
        self.cfg = cfg
        self.dram = dram
        self.evq = evq
        self.merged = 0
        self.requests = 0

    def request(self, cycle: int, line_addr: int, sm_id: int, cb: Callable,
                write: bool = False):
        self.requests += 1
        self.dram.access(cycle, line_addr, cb)

    def request_many(self, cycle: int, lines, sm_id: int, cb: Callable,
                     write: bool = False):
        self.requests += len(lines)
        dram = self.dram
        for line_addr in lines:
            dram.access(cycle, line_addr, cb)

    def stats(self):
        return {"requests": self.requests, "hits": 0, "misses": self.requests,
                "mshr_merges": 0, "rc_inserts": 0}


def build_memory(cfg: GPUMachine, evq: EventQueue, scale: float = 1.0,
                 seed: int = 0, direct: bool = False):
    dram = DRAM(cfg, evq, scale)
    if direct:
        front = DirectHBM(cfg, dram, evq)
        return front, front, dram
    l2 = L2Cache(cfg, dram, evq, scale, seed)
    lrc = LRC(cfg, l2)
    return lrc, l2, dram
