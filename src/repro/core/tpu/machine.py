"""TPU v5e core model for the Sim-FA engine (hardware adaptation, DESIGN §3).

The event engine is reused with TPU semantics:
  * one "SM" = one TensorCore; the single "CTA" = the Pallas grid walk;
  * producer WG = the async DMA engine streaming HBM->VMEM tiles (the TMA
    analogue: same ACQUIRE/RELEASE ring-buffer discipline Mosaic's
    multi-buffered pipeline implements in hardware);
  * consumer WG = MXU matmuls (WGMMA instrs with precomputed cycles) + VPU
    softmax (BUBBLES);
  * memory = DirectHBM (no shared L2 on TPU; bandwidth/latency channels).
"""
from __future__ import annotations

import math

from repro.core.machine import GPUMachine, TPUMachine, TPU_V5E


def tpu_engine_machine(tpu: TPUMachine = TPU_V5E) -> GPUMachine:
    """GPUMachine-shaped parameterization of one TPU chip for the engine."""
    bytes_per_cycle = tpu.hbm_gbps * 1e9 / (tpu.freq_ghz * 1e9)
    lines_per_cycle = max(1, int(round(bytes_per_cycle / 128)))
    return GPUMachine(
        name=tpu.name,
        freq_ghz=tpu.freq_ghz,
        num_sms=tpu.num_cores,
        peak_tflops_fp16=tpu.peak_tflops_bf16,
        wgmma_issue_buffer=16,
        wgmma_n_cycles_divisor=2.0,          # unused: cycles precomputed
        issue_width=1,
        tma_lines_per_cycle=lines_per_cycle, # DMA streaming rate cap
        tma_max_inflight_lines=4096,         # deep HBM pipelining
        tma_launch_latency=tpu.dma_launch_latency,
        tma_tmap_setup_latency=0,            # BlockSpec: no descriptor cache
        l2_bytes=0, l2_slices=2,             # unused in direct mode
        lrc_enabled=False, remote_copy=False,
        dram_channels=16,
        dram_bw_gbps=tpu.hbm_gbps,
        dram_latency=int(500 * tpu.freq_ghz),   # ~500ns HBM latency
        occupancy_limit=1,                   # one resident grid per core
    )


def mxu_cycles(tpu: TPUMachine, m: int, n: int, k: int) -> int:
    """Cycles for an (m,k)x(k,n) bf16 matmul: operands pad to the 128x128
    systolic tile, so sub-128 block dims waste MXU occupancy."""
    mt, nt = tpu.mxu_shape
    m_pad = math.ceil(m / mt) * mt
    n_pad = math.ceil(n / nt) * nt
    return max(1, int(math.ceil(m_pad * n_pad * k / tpu.mxu_macs_per_cycle)))


def mxu_efficiency(tpu: TPUMachine, m: int, n: int) -> float:
    mt, nt = tpu.mxu_shape
    m_pad = math.ceil(m / mt) * mt
    n_pad = math.ceil(n / nt) * nt
    return (m * n) / (m_pad * n_pad)


def vpu_softmax_cycles(tpu: TPUMachine, rows: int, cols: int) -> int:
    """Online-softmax VPU work for one (rows x cols) score tile:
    rowmax + exp + rowsum + rescale accumulate."""
    elems = rows * cols
    expc = math.ceil(elems / tpu.vpu_exp_per_cycle)
    other = math.ceil(3 * elems / tpu.vpu_flops_per_cycle)
    return expc + other
