"""SimFA-TPU analytical model: the paper's §3 traffic methodology mapped to
the TPU memory hierarchy (DESIGN.md §3).

"L2 traffic" ↦ core-side demand traffic (VMEM fills), "DRAM" ↦ HBM. The
wave model becomes a Q-row-block model: each of ceil(L/bq) grid rows
re-streams the K/V head from HBM unless the whole K/V head fits the VMEM
budget (the Eq. 4 analogue — on TPU the refetch factor is structural, set
by the kernel's loop order, not by cache capacity luck).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.llama3 import AttnWorkload
from repro.core.machine import TPUMachine, TPU_V5E
from repro.core.tpu.machine import mxu_efficiency


@dataclass(frozen=True)
class TPUTrafficReport:
    flops: float
    hbm_bytes_ideal: float          # K/V resident in VMEM (Eq. 3 analogue)
    hbm_bytes_real: float           # refetch per Q row block (Eq. 6 analogue)
    kv_resident: bool               # Eq. 4 analogue
    refetch_factor: int
    vmem_tile_bytes: int            # working set claimed by the BlockSpecs
    t_compute: float
    t_hbm: float
    t_vpu: float

    @property
    def hbm_bytes(self):
        return self.hbm_bytes_ideal if self.kv_resident else self.hbm_bytes_real

    @property
    def latency(self) -> float:
        return max(self.t_compute, self.t_hbm, self.t_vpu)

    @property
    def bottleneck(self) -> str:
        t = {"mxu": self.t_compute, "hbm": self.t_hbm, "vpu": self.t_vpu}
        return max(t, key=t.get)


def analyze_tpu(w: AttnWorkload, tpu: TPUMachine = TPU_V5E, *, bq: int = 128,
                bk: int = 128, stages: int = 2, causal: bool = True,
                vmem_budget_frac: float = 0.6) -> TPUTrafficReport:
    H_q = w.H_kv * w.G
    causal_f = 0.5 if causal else 1.0
    flops = 4.0 * w.B * H_q * w.L * w.S * w.D * causal_f

    P = w.P
    q_o = 2 * P * w.B * H_q * w.L * w.D
    kv_once = 2 * P * w.B * w.H_kv * w.S * w.D
    n_rows = math.ceil(w.L / bq)                     # Q row blocks per head
    # GQA: G consecutive q-heads share a KV head; a core streams the KV head
    # once per (q-head, row-block) -> refetch = G * n_rows (per chip, single
    # core; multi-chip head-sharding divides both sides equally)
    refetch = max(1, G_rows := w.G * n_rows)
    kv_head_bytes = 2 * P * w.S * w.D
    kv_resident = kv_head_bytes <= tpu.vmem_bytes * vmem_budget_frac
    ideal = q_o + kv_once
    real = q_o + kv_once * refetch * causal_f
    vmem_tile = P * (bq * w.D + 2 * stages * bk * w.D) + 4 * bq * w.D + 4 * bq * bk

    eff = min(mxu_efficiency(tpu, bq, bk), mxu_efficiency(tpu, bq, w.D))
    t_c = flops / (tpu.peak_tflops_bf16 * 1e12 * eff)
    t_h = (ideal if kv_resident else real) / (tpu.hbm_gbps * 1e9)
    # VPU: ~4 elementwise passes over the score tiles
    score_elems = w.B * H_q * w.L * w.S * causal_f
    vpu_ops_per_s = tpu.vpu_exp_per_cycle * tpu.freq_ghz * 1e9
    t_v = 2.0 * score_elems / vpu_ops_per_s
    return TPUTrafficReport(
        flops=flops, hbm_bytes_ideal=ideal, hbm_bytes_real=real,
        kv_resident=kv_resident, refetch_factor=refetch,
        vmem_tile_bytes=int(vmem_tile), t_compute=t_c, t_hbm=t_h, t_vpu=t_v)
