"""Sim-guided kernel autotuning: pick flash-attention block sizes the way
FA3 picks T_M/T_N — by modeling the pipeline, not by hand (paper §2.2: "the
final pipeline stages and block sizes are determined through profiling"; we
substitute SimFA-TPU for the profiler).

``autotune_flash`` sweeps (block_q, block_k, stages) through the analytical
model, short-lists by predicted latency, then (optionally) cycle-simulates
the short-list for the final pick. The framework consumes this through
``kernel_plan`` in ops/benchmarks and §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import TPUMachine, TPU_V5E
from repro.core.tpu.analytical import analyze_tpu
from repro.core.tpu.machine import tpu_engine_machine
from repro.core.tpu.tracegen import flash_grid_trace

BLOCK_CHOICES = (64, 128, 256, 512)
STAGE_CHOICES = (2, 3)


@dataclass
class KernelPlan:
    block_q: int
    block_k: int
    stages: int
    predicted_us: float
    bottleneck: str
    vmem_bytes: int
    sim_us: Optional[float] = None


def _fits_vmem(w, bq, bk, stages, tpu, frac=0.7) -> bool:
    rep = analyze_tpu(w, tpu, bq=bq, bk=bk, stages=stages)
    return rep.vmem_tile_bytes <= tpu.vmem_bytes * frac


def autotune_flash(w: AttnWorkload, tpu: TPUMachine = TPU_V5E, *,
                   causal: bool = True, use_sim: bool = False,
                   sim_rows: int = 2, top_k: int = 3) -> KernelPlan:
    cands: List[KernelPlan] = []
    for bq in BLOCK_CHOICES:
        if bq > w.L:
            continue
        for bk in BLOCK_CHOICES:
            if bk > w.S:
                continue
            for st in STAGE_CHOICES:
                if not _fits_vmem(w, bq, bk, st, tpu):
                    continue
                rep = analyze_tpu(w, tpu, bq=bq, bk=bk, stages=st,
                                  causal=causal)
                cands.append(KernelPlan(
                    block_q=bq, block_k=bk, stages=st,
                    predicted_us=rep.latency * 1e6,
                    bottleneck=rep.bottleneck,
                    vmem_bytes=rep.vmem_tile_bytes))
    if not cands:
        return KernelPlan(min(128, w.L), min(128, w.S), 2, 0.0, "mxu", 0)
    # tie-break equal latencies toward larger tiles (fewer grid steps,
    # better DMA amortization)
    cands.sort(key=lambda c: (round(c.predicted_us, 3), -c.block_q * c.block_k))
    if not use_sim:
        return cands[0]

    # cycle-simulate the analytical short-list on a few grid rows
    best = None
    for c in cands[:top_k]:
        cta, tmaps = flash_grid_trace(
            w, tpu, bq=c.block_q, bk=c.block_k, stages=c.stages,
            causal=causal, max_grid_rows=sim_rows)
        eng = Engine(tpu_engine_machine(tpu), n_sms=1, mem_scale=1.0,
                     direct_hbm=True)
        for tm in tmaps.values():
            eng.define_tmap(tm)
        eng.launch([cta])
        st = eng.run()
        c.sim_us = st["time_us"]
        if best is None or c.sim_us < best.sim_us:
            best = c
    return best
