"""Trace generation for OUR Pallas flash-attention kernel on TPU.

Walks the same (b, h, i, j) grid as kernels/flash_attention.py and emits the
pipeline the Mosaic compiler builds: multi-buffered async DMA of K/V tiles
(ring stages, the TMA analogue) overlapped with MXU matmuls and VPU softmax.
This is the TPU-mode counterpart of tracegen_fa3.py (hardware adaptation).
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.configs.llama3 import AttnWorkload
from repro.core import isa
from repro.core.engine import CTATrace
from repro.core.isa import Instr, TensorMap
from repro.core.machine import TPUMachine, TPU_V5E
from repro.core.tpu.machine import mxu_cycles, vpu_softmax_cycles

TM_Q, TM_K, TM_V, TM_O = 0, 1, 2, 3


def tpu_tmaps(w: AttnWorkload, bq: int, bk: int) -> Dict[int, TensorMap]:
    P = w.P
    H_q = w.H_kv * w.G
    sz_q = w.B * H_q * w.L * w.D * P
    sz_kv = w.B * w.H_kv * w.S * w.D * P
    return {
        TM_Q: TensorMap(TM_Q, 0, (w.B * H_q, w.L, w.D),
                        (w.L * w.D * P, w.D * P, P), (1, bq, w.D), P),
        TM_K: TensorMap(TM_K, sz_q, (w.B * w.H_kv, w.S, w.D),
                        (w.S * w.D * P, w.D * P, P), (1, bk, w.D), P),
        TM_V: TensorMap(TM_V, sz_q + sz_kv, (w.B * w.H_kv, w.S, w.D),
                        (w.S * w.D * P, w.D * P, P), (1, bk, w.D), P),
        TM_O: TensorMap(TM_O, sz_q + 2 * sz_kv, (w.B * H_q, w.L, w.D),
                        (w.L * w.D * P, w.D * P, P), (1, bq, w.D), P),
    }


def flash_grid_trace(w: AttnWorkload, tpu: TPUMachine = TPU_V5E, *,
                     bq: int = 128, bk: int = 128, stages: int = 2,
                     causal: bool = True, defer_pv_wait: bool = True,
                     max_grid_rows: int | None = None) -> Tuple[CTATrace, Dict[int, TensorMap]]:
    """One TensorCore's sequential walk over the flash grid.

    Producer(DMA) / consumer(MXU+VPU) as two logical threads sharing a
    ``stages``-deep VMEM ring buffer — exactly the Pallas pipeline.
    """
    H_q = w.H_kv * w.G
    n_i = math.ceil(w.L / bq)
    n_j_full = math.ceil(w.S / bk)
    qk_cyc = mxu_cycles(tpu, bq, bk, w.D)
    pv_cyc = mxu_cycles(tpu, bq, w.D, bk)
    sm_cyc = vpu_softmax_cycles(tpu, bq, bk)

    prod: List[Instr] = []
    cons: List[Instr] = []
    rows = 0
    gid = 0
    for bh in range(w.B * H_q):
        hkv = (bh % H_q) // w.G
        for i in range(n_i):
            if max_grid_rows and rows >= max_grid_rows:
                break
            rows += 1
            n_j = n_j_full if not causal else min(
                n_j_full, math.ceil(((i + 1) * bq) / bk))
            # Q tile for this row of the grid
            qsid = 90 + (rows % 4)
            prod.append(Instr(isa.TMA_TENSOR, map_id=TM_Q, sid=qsid,
                              origin=(bh, i * bq, 0), tag=f"Q{i}"))
            cons.append(Instr(isa.MB_WAIT, sid=qsid))

            def _load(j):
                sk = 2 * (j % stages)
                prod.append(Instr(isa.ACQUIRE_STAGE, sid=sk))
                prod.append(Instr(isa.TMA_TENSOR, map_id=TM_K, sid=sk,
                                  origin=(hkv, j * bk, 0), tag=f"K{j}"))
                prod.append(Instr(isa.ACQUIRE_STAGE, sid=sk + 1))
                prod.append(Instr(isa.TMA_TENSOR, map_id=TM_V, sid=sk + 1,
                                  origin=(hkv, j * bk, 0), tag=f"V{j}"))

            # software-pipelined consumer: QK_{j+1} issues before softmax_j
            # so the MXU overlaps the VPU (Mosaic's cross-iteration ILP).
            qk_gid = {}
            for j in range(n_j):
                _load(j)
            cons.append(Instr(isa.MB_WAIT, sid=0))
            cons.append(Instr(isa.WGMMA, gid=gid, m=bq, n=bk, k=w.D,
                              cycles=qk_cyc, tag="QK0"))
            cons.append(Instr(isa.WGMMA_COMMIT, gid=gid))
            qk_gid[0] = gid
            gid += 1
            prev_pv = None   # (gid, sv) of the previous iteration's PV
            for j in range(n_j):
                sk, sv = 2 * (j % stages), 2 * (j % stages) + 1
                if j + 1 < n_j:
                    skn = 2 * ((j + 1) % stages)
                    cons.append(Instr(isa.MB_WAIT, sid=skn))
                    cons.append(Instr(isa.WGMMA, gid=gid, m=bq, n=bk, k=w.D,
                                      cycles=qk_cyc, tag=f"QK{j+1}"))
                    cons.append(Instr(isa.WGMMA_COMMIT, gid=gid))
                    qk_gid[j + 1] = gid
                    gid += 1
                cons.append(Instr(isa.WGMMA_WAIT, gid=qk_gid[j], n=0))
                cons.append(Instr(isa.RELEASE_STAGE, sid=sk))
                cons.append(Instr(isa.BUBBLES, cycles=sm_cyc))
                cons.append(Instr(isa.MB_WAIT, sid=sv))
                cons.append(Instr(isa.WGMMA, gid=gid, m=bq, n=w.D, k=bk,
                                  cycles=pv_cyc, tag=f"PV{j}"))
                cons.append(Instr(isa.WGMMA_COMMIT, gid=gid))
                if defer_pv_wait:
                    # §Perf iteration 1: wait on the PREVIOUS PV instead of
                    # the one just issued — PV_j computes under softmax_{j+1}
                    # (needs stages >= 2 so V_j's slot isn't recycled early)
                    if prev_pv is not None:
                        cons.append(Instr(isa.WGMMA_WAIT, gid=prev_pv[0], n=1))
                        cons.append(Instr(isa.RELEASE_STAGE, sid=prev_pv[1]))
                    prev_pv = (gid, sv)
                else:
                    cons.append(Instr(isa.WGMMA_WAIT, gid=gid, n=0))
                    cons.append(Instr(isa.RELEASE_STAGE, sid=sv))
                gid += 1
            if defer_pv_wait and prev_pv is not None:
                cons.append(Instr(isa.WGMMA_WAIT, gid=prev_pv[0], n=0))
                cons.append(Instr(isa.RELEASE_STAGE, sid=prev_pv[1]))
            cons.append(Instr(isa.TMA_STORE, map_id=TM_O, gid=gid,
                              origin=(bh, i * bq, 0), tag=f"O{i}"))
            cons.append(Instr(isa.TMA_COMMIT, gid=gid))
            cons.append(Instr(isa.TMA_WAIT, gid=gid, n=0))
            gid += 1
    return (CTATrace(wgs=[prod, cons], n_consumers=1, name="tpu-flash"),
            tpu_tmaps(w, bq, bk))
