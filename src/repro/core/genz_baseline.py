"""GenZ-like ideal-cache baseline (paper §6.2.3 comparison).

GenZ-style analytical models count DRAM traffic under an ideal-cache
assumption: every tensor moves between DRAM and the chip exactly once. The
paper shows this slightly *over*-estimates at short sequences (no credit for
request coalescing of Q/O partial lines) and severely *under*-estimates at
long sequences (no capacity-induced K/V refetch). We reproduce that
baseline so benchmarks can plot both against SimFA-python.
"""
from __future__ import annotations

from repro.configs.llama3 import AttnWorkload
from repro.core.machine import GPUMachine


def genz_dram_traffic(w: AttnWorkload) -> float:
    """Ideal-cache DRAM bytes: Q + K + V read once, O written once."""
    q_o = 2 * w.P * w.B * (w.H_kv * w.G) * w.L * w.D
    kv = 2 * w.P * w.B * w.H_kv * w.S * w.D
    return q_o + kv


def genz_latency(w: AttnWorkload, cfg: GPUMachine) -> float:
    """max(compute, DRAM) roofline — no L2 term, no wave model."""
    f = 4.0 * w.B * (w.H_kv * w.G) * w.L * w.S * w.D
    if w.causal:
        f /= 2
    t_c = f / (cfg.peak_tflops_fp16 * 1e12)
    t_d = genz_dram_traffic(w) / (cfg.dram_bw_gbps * 1e9)
    return max(t_c, t_d)
