"""FA3 trace generation (paper §5.1-§5.2, Table 4).

Reproduces the offline trace translation: one producer WarpGroup + two
consumer WarpGroups per CTA with ping-pong scheduling. Each GEMM issue
expands into D/16 (QK) resp. ceil(T_N/16) (PV) WGMMA instructions sharing a
group id; softmax/rowmax/rowsum/convert/rescale become a bubble block whose
cycle count follows the §5.2 throughput arithmetic (988 cycles at
T_M=64, T_N=176, D=128).

Having no H800 to instrument, the "runtime log" phase is replaced by a
schedule-exact generator that walks the same loop structure as the FA3
kernel — the translation rules from events to instructions are the paper's.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core import isa
from repro.core.engine import CTATrace
from repro.core.isa import Instr, TensorMap
from repro.core.machine import GPUMachine


@dataclass(frozen=True)
class FA3Tiling:
    t_m: int = 64          # query rows per CTA (per paper §5.2)
    t_n: int = 176         # kv tile rows
    stages: int = 2        # ring-buffer stages for K and V each
    precision: int = 2     # fp16


def softmax_bubble_cycles(cfg: GPUMachine, t_m: int, t_n: int, d: int) -> int:
    """§5.2 bubble arithmetic for one (T_M x T_N) tile per consumer WG."""
    elems = t_m * t_n
    rowmax = math.ceil(elems / cfg.fp32_ops_per_cycle)        # 88 @ 64x176
    expo = math.ceil(elems / cfg.mufu_ops_per_cycle)          # 704
    rowsum = math.ceil(elems / cfg.fp32_ops_per_cycle)        # 88
    cvt = math.ceil(elems / cfg.fp16_ops_per_cycle)           # 44
    rescale = math.ceil(t_m * d / cfg.fp16_ops_per_cycle)     # 64
    return rowmax + expo + rowsum + cvt + rescale             # = 988


# tensor-map ids
TM_Q, TM_K, TM_V, TM_O = 0, 1, 2, 3


def make_tmaps(B: int, L: int, S: int, H_q: int, H_kv: int, D: int,
               tiling: FA3Tiling, base: int = 0) -> Dict[int, TensorMap]:
    """Layouts follow the FA3 kernel's (B, S, H, D) tensors: consecutive
    sequence rows of one head are H*D*P bytes apart — the 2048-byte strides
    that concentrate requests on L2 slices under a naive low-bit hash
    (paper §5.4). A head's tile is addressed via an inner-dim origin offset
    of h*D elements."""
    P = tiling.precision
    sz_q = B * L * H_q * D * P
    sz_kv = B * S * H_kv * D * P
    return {
        TM_Q: TensorMap(TM_Q, base, (B, L, H_q * D),
                        (L * H_q * D * P, H_q * D * P, P),
                        (1, tiling.t_m, D), P),
        TM_K: TensorMap(TM_K, base + sz_q, (B, S, H_kv * D),
                        (S * H_kv * D * P, H_kv * D * P, P),
                        (1, tiling.t_n, D), P),
        TM_V: TensorMap(TM_V, base + sz_q + sz_kv, (B, S, H_kv * D),
                        (S * H_kv * D * P, H_kv * D * P, P),
                        (1, tiling.t_n, D), P),
        TM_O: TensorMap(TM_O, base + sz_q + 2 * sz_kv, (B, L, H_q * D),
                        (L * H_q * D * P, H_q * D * P, P),
                        (1, tiling.t_m, D), P),
    }


def fa3_cta_trace(cfg: GPUMachine, *, b: int, h_q: int, h_kv: int,
                  q_block: int, S: int, D: int, tiling: FA3Tiling,
                  causal: bool = False, q_base_row: int = 0) -> CTATrace:
    """Trace for one CTA covering q rows [q_block*t_m, ...) of head h_q.

    WG0 = producer, WG1/WG2 = consumers (ping-pong). Ring-buffer slot ids:
    K tiles use sid = 2*(j % stages), V tiles sid = 2*(j % stages)+1.
    """
    t_m, t_n, stages = tiling.t_m, tiling.t_n, tiling.stages
    n_tiles = math.ceil(S / t_n)
    if causal:
        last_row = q_base_row + q_block * t_m + t_m - 1
        n_tiles = min(n_tiles, math.ceil((last_row + 1) / t_n))
    bubbles = softmax_bubble_cycles(cfg, t_m, t_n, D)
    n_qk = D // 16                      # 8 WGMMAs per QK GEMM (§5.2)
    n_pv = math.ceil(t_n / 16)          # 11 WGMMAs per PV GEMM

    prod: List[Instr] = []
    cons: List[List[Instr]] = [[], []]

    # producer: Q first, then stream K/V tiles through the ring buffer
    prod.append(Instr(isa.TMA_TENSOR, map_id=TM_Q, sid=98,
                      origin=(b, q_block * t_m, h_q * D), tag="Q"))
    for j in range(n_tiles):
        sk = 2 * (j % stages)
        sv = sk + 1
        prod.append(Instr(isa.ACQUIRE_STAGE, sid=sk))
        prod.append(Instr(isa.TMA_TENSOR, map_id=TM_K, sid=sk,
                          origin=(b, j * t_n, h_kv * D), tag=f"K{j}"))
        prod.append(Instr(isa.ACQUIRE_STAGE, sid=sv))
        prod.append(Instr(isa.TMA_TENSOR, map_id=TM_V, sid=sv,
                          origin=(b, j * t_n, h_kv * D), tag=f"V{j}"))

    # consumers: ping-pong via two named barriers (bid 0 = MMA token,
    # bid 1 = softmax token). BAR_WAIT.n is an absolute arrival threshold.
    for c in (0, 1):
        tr = cons[c]
        tr.append(Instr(isa.MB_WAIT, sid=98))          # Q ready
        gid = 0
        mma_arr = 0                                     # arrivals we produced
        for j in range(n_tiles):
            sk = 2 * (j % stages)
            sv = sk + 1
            tr.append(Instr(isa.MB_WAIT, sid=sk))       # K tile ready
            if c == 0:
                # consumer 1 announces it's entering MMA; consumer 2 waits
                tr.append(Instr(isa.BAR_ARRIVE, bid=0))
            else:
                tr.append(Instr(isa.BAR_WAIT, bid=0, n=j + 1))
            for _ in range(n_qk):
                tr.append(Instr(isa.WGMMA, gid=gid, m=t_m, n=t_n, k=16,
                                tag=f"QK{j}"))
            tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
            tr.append(Instr(isa.WGMMA_WAIT, gid=gid, n=1))   # WAIT_WG_1
            tr.append(Instr(isa.RELEASE_STAGE, sid=sk))      # K done (§5.2)
            if c == 0:
                tr.append(Instr(isa.BAR_WAIT, bid=1, n=j + 1))
            else:
                tr.append(Instr(isa.BAR_ARRIVE, bid=1))
            tr.append(Instr(isa.BUBBLES, cycles=bubbles))    # softmax block
            tr.append(Instr(isa.MB_WAIT, sid=sv))            # V tile ready
            gid += 1
            for _ in range(n_pv):
                tr.append(Instr(isa.WGMMA, gid=gid, m=t_m, n=D, k=16,
                                tag=f"PV{j}"))
            tr.append(Instr(isa.WGMMA_COMMIT, gid=gid))
            tr.append(Instr(isa.WGMMA_WAIT, gid=gid, n=0))   # WAIT_WG_0
            tr.append(Instr(isa.RELEASE_STAGE, sid=sv))      # V done
            gid += 1
        # epilogue: store O tile
        tr.append(Instr(isa.TMA_STORE, map_id=TM_O, gid=99,
                        origin=(b, q_block * t_m, h_q * D), tag="O"))
        tr.append(Instr(isa.TMA_COMMIT, gid=99))
        tr.append(Instr(isa.TMA_WAIT, gid=99, n=0))

    return CTATrace(wgs=[prod] + cons, n_consumers=2,
                    name=f"b{b}h{h_q}q{q_block}")


def fa3_kernel_ctas(cfg: GPUMachine, *, B: int, H_kv: int, G: int, L: int,
                    S: int, D: int, tiling: FA3Tiling = FA3Tiling(),
                    causal: bool = False,
                    max_ctas: int | None = None) -> Tuple[List[CTATrace], Dict[int, TensorMap]]:
    """All CTAs of one FA3 launch: B*H_kv*G heads x ceil(L/T_M) q-blocks.

    CTA order follows the kernel's (head-major) rasterization so that one
    wave works on as few distinct KV heads as possible — the reuse structure
    behind Eq. (5)/(6).
    """
    tmaps = make_tmaps(B, L, S, H_kv * G, H_kv, D, tiling)
    ctas = []
    n_q = math.ceil(L / tiling.t_m)
    for b in range(B):
        for hkv in range(H_kv):
            for g in range(G):
                hq = hkv * G + g
                for qb in range(n_q):
                    ctas.append(fa3_cta_trace(
                        cfg, b=b, h_q=hq, h_kv=hkv,
                        q_block=qb, S=S, D=D, tiling=tiling, causal=causal))
                    if max_ctas and len(ctas) >= max_ctas:
                        return ctas, tmaps
    return ctas, tmaps
