"""FA3 trace generation — compatibility front end over the kernel IR.

The hardcoded generator this module used to contain now lives as the
registered ``fa3`` ping-pong :class:`~repro.core.kprog.ir.KernelSpec`
(``repro.core.kprog.fa3``); lowering through the IR is instruction-for-
instruction identical (``tests/test_kprog.py``), so the public helpers here
keep their historical signatures for the benchmarks and tests that import
them.  New code should go through ``repro.core.kprog`` / the kernel
registry instead.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Dict, List, Optional, Tuple

from repro.core.engine import CTATrace
from repro.core.isa import TensorMap
from repro.core.kprog.costs import softmax_bubble_cycles  # noqa: F401
from repro.core.kprog.fa3 import (TM_K, TM_O, TM_Q, TM_V,  # noqa: F401
                                  FA3_SPEC, FA3Tiling, make_tmaps)
from repro.core.machine import GPUMachine

__all__ = ["FA3Tiling", "softmax_bubble_cycles", "make_tmaps",
           "fa3_cta_trace", "fa3_kernel_ctas",
           "TM_Q", "TM_K", "TM_V", "TM_O"]


def fa3_cta_trace(cfg: GPUMachine, *, b: int, h_q: int, h_kv: int,
                  q_block: int, S: int, D: int, tiling: FA3Tiling,
                  causal: bool = False, q_base_row: int = 0) -> CTATrace:
    """Trace for one CTA covering q rows [q_block*t_m, ...) of head h_q."""
    w = SimpleNamespace(S=S, D=D, causal=causal)
    return FA3_SPEC.cta(cfg, w, tiling, b=b, h_q=h_q, h_kv=h_kv,
                        q_block=q_block, q_base_row=q_base_row)


def fa3_kernel_ctas(cfg: GPUMachine, *, B: int, H_kv: int, G: int, L: int,
                    S: int, D: int, tiling: FA3Tiling = FA3Tiling(),
                    causal: bool = False,
                    max_ctas: Optional[int] = None
                    ) -> Tuple[List[CTATrace], Dict[int, TensorMap]]:
    """All CTAs of one FA3 launch: B*H_kv*G heads x ceil(L/T_M) q-blocks,
    head-major rasterized.  ``max_ctas=0`` builds zero CTAs (the historic
    falsy-guard accident that treated 0 as "unlimited" is gone)."""
    w = SimpleNamespace(B=B, H_kv=H_kv, G=G, L=L, S=S, D=D, causal=causal)
    return FA3_SPEC.build(cfg, w, tiling=tiling, max_ctas=max_ctas)
