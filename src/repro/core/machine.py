"""Machine configurations for Sim-FA.

``H800`` mirrors the paper's Table 2 (the faithful GPU-mode reproduction);
``TPU_V5E`` is the hardware-adaptation target (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class GPUMachine:
    name: str = "H800-SXM"
    freq_ghz: float = 1.83                 # locked frequency (paper §5.3)
    num_sms: int = 132                     # 66 TPCs
    peak_tflops_fp16: float = 989.0

    # SM / TensorCore
    wgmma_issue_buffer: int = 16
    wgmma_n_cycles_divisor: float = 2.0    # FP16 m64nNk16 completes in ~N/2
    issue_width: int = 1                   # trace instructions per SM-cycle
    mufu_ops_per_cycle: int = 16           # exp throughput per SM
    fp32_ops_per_cycle: int = 128          # per WarpGroup (4x32 lanes)
    fp16_ops_per_cycle: int = 256

    # TMA engine (per SM)
    tma_lines_per_cycle: int = 2
    tma_max_inflight_lines: int = 64
    tma_launch_latency: int = 40           # common launch overhead
    tma_tmap_setup_latency: int = 130      # TensorMap descriptor path only

    # L2
    l2_bytes: int = 50 * 1024 * 1024
    l2_slices: int = 80
    l2_near_latency: int = 258
    l2_far_latency: int = 414
    l2_req_q: int = 32
    l2_resp_q: int = 128
    l2_mshr_per_slice: int = 256           # calibrated (paper Fig. 4)
    line_bytes: int = 128
    xor_hash: bool = True                  # slice = (line ^ line>>5) % N
    lrc_enabled: bool = True               # L2 Request Coalescer per SM pair
    tma_dedup: bool = True                 # dedup lines during addr generation

    # RemoteCopy partition proxy (paper §4.3): calibrated once against the
    # qualitative H800 latency curve (floor / 25-50MB window / plateau),
    # then held fixed across all experiments
    remote_copy: bool = True
    rc_max_prob: float = 0.5
    rc_occupancy_threshold: float = 0.9

    # DRAM (HBM3-5200, 80 channels, bandwidth/latency model; DESIGN.md §3)
    dram_channels: int = 80
    dram_bw_gbps: float = 3350.0           # H800 SXM aggregate
    dram_latency: int = 400                # cycles beyond L2

    occupancy_limit: int = 2               # CTAs resident per SM for FA3

    @property
    def dram_line_service_cycles(self) -> float:
        """Cycles for one 128B line per channel at aggregate bandwidth."""
        bytes_per_cycle = self.dram_bw_gbps * 1e9 / (self.freq_ghz * 1e9)
        per_chan = bytes_per_cycle / self.dram_channels
        return self.line_bytes / per_chan


@dataclass(frozen=True)
class TPUMachine:
    """TPU v5e-class single chip (the adaptation target; prompt constants)."""
    name: str = "TPU-v5e"
    freq_ghz: float = 0.94
    num_cores: int = 1                     # TensorCores per chip
    peak_tflops_bf16: float = 197.0
    hbm_gbps: float = 819.0
    ici_gbps_per_link: float = 50.0
    vmem_bytes: int = 128 * 1024 * 1024
    mxu_shape: tuple = (128, 128)
    # DMA modeling (TMA analogue): issue overhead + per-line streaming
    dma_launch_latency: int = 150          # descriptor/setup cycles
    dma_bytes_per_cycle: float = 819e9 / 0.94e9   # HBM-bound streaming
    vpu_exp_per_cycle: int = 8 * 128       # 8x128 VPU lanes, 1 exp/lane
    vpu_flops_per_cycle: int = 8 * 128 * 2

    @property
    def mxu_macs_per_cycle(self) -> float:
        return self.peak_tflops_bf16 * 1e12 / (self.freq_ghz * 1e9) / 2


H800 = GPUMachine()
TPU_V5E = TPUMachine()


def h800_variant(**kw) -> GPUMachine:
    return replace(H800, **kw)


# Measured Hopper variability envelopes (PAPERS.md microbenchmarking
# studies: arxiv 2501.12084 reports the L2 near/far and DRAM latency
# spreads around the means Table 2 pins; arxiv 2402.13499 the sustained
# clock excursions under power capping).  Kept out of ``GPUMachine`` on
# purpose: the calibrated constant-parameter model stays the paper's
# locked-frequency ideal, and ``repro.faults.measured_variability`` turns
# these envelopes into a seeded :class:`~repro.faults.FaultPlan` when a
# run should sample realistic spread instead.  Values are one-standard-
# deviation extra-latency envelopes in cycles (latencies) or a sustained
# derate factor (throttle).
H800_VARIABILITY = {
    "dram_jitter_std": 24.0,        # ~6% of the 400-cycle DRAM latency
    "l2_near_jitter_std": 10.0,     # near-partition lookup spread
    "l2_far_jitter_std": 22.0,      # far-partition (cross-GPC) spread
    "tma_jitter_std": 6.0,          # descriptor/launch path spread
    "completion_jitter_std": 4.0,   # async-completion delivery spread
    "throttle_factor": 1.06,        # sustained power-cap compute derate
}
