"""Paper Fig. 7 — FlashAttention-3 pipeline Gantt chart (SM 0).

Runs the 405B-config FA3 pipeline on a single simulated SM with gantt
recording, renders the text chart, and checks the two structural properties
the figure demonstrates: (1) producer TMA overlaps consumer WGMMA, and
(2) the two consumers ping-pong (their softmax bubbles interleave with each
other's MMA phases rather than stacking).
"""
from __future__ import annotations


from repro.configs.llama3 import workload
from repro.core.gantt import render_text
from repro.core.engine import Engine
from repro.core.machine import H800
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas

from benchmarks.common import RESULTS_DIR, Sink


def _intervals(gantt, prefix):
    return sorted((s, e) for tag, s, e in gantt if tag.startswith(prefix))


def _overlap(a, b):
    """Total overlapped cycles between two sorted interval lists."""
    tot = 0
    for s1, e1 in a:
        for s2, e2 in b:
            lo, hi = max(s1, s2), min(e1, e2)
            if hi > lo:
                tot += hi - lo
    return tot


def run(sink: Sink):
    cfg = H800
    w = workload("405B", 6144, batch=1)
    tiling = FA3Tiling()
    # one SM, occupancy-limit CTAs resident — Fig. 7 shows SM 0
    ctas, tmaps = fa3_kernel_ctas(
        cfg, B=1, H_kv=w.H_kv, G=w.G, L=w.L, S=w.S, D=w.D, tiling=tiling,
        max_ctas=cfg.occupancy_limit)
    eng = Engine(cfg, n_sms=1, mem_scale=1.0 / cfg.num_sms, record_gantt=True)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    st = eng.run()
    gantt = eng.gantt()

    chart = render_text(gantt, width=110)
    out = RESULTS_DIR / "fa3_gantt.txt"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(chart + "\n")

    # structural checks (lanes keyed by the kernel IR's declared roles)
    tma_prod = _intervals(gantt, "tma:cta0/producer")
    mma_c1 = _intervals(gantt, "mma:cta0/consumer0")
    mma_c2 = _intervals(gantt, "mma:cta0/consumer1")
    bub_c1 = _intervals(gantt, "bubble:cta0/consumer0")
    bub_c2 = _intervals(gantt, "bubble:cta0/consumer1")

    ov_tma_mma = _overlap(tma_prod, mma_c1 + mma_c2)
    ov_pingpong = _overlap(bub_c1, mma_c2) + _overlap(bub_c2, mma_c1)
    ov_self = _overlap(bub_c1, mma_c1) + _overlap(bub_c2, mma_c2)
    mma_busy = sum(e - s for s, e in mma_c1 + mma_c2)
    bub_busy = sum(e - s for s, e in bub_c1 + bub_c2)

    sink.row(cycles=st["cycles"], tc_util=round(st["tc_util"], 3),
             tma_mma_overlap_cycles=ov_tma_mma,
             pingpong_overlap_cycles=ov_pingpong,
             mma_busy=mma_busy, softmax_busy=bub_busy)
    sink.derive(
        chart_file=str(out),
        producer_overlaps_consumer=ov_tma_mma > 0.1 * mma_busy,
        pingpong_hides_softmax=ov_pingpong > 0.3 * bub_busy,
        own_mma_softmax_overlap_cycles=ov_self,   # intra-WG async WGMMA tail
    )
    print(chart)
