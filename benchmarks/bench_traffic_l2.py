"""Paper Fig. 8 — L2 (LLC) demand-traffic validation of SimFA-python.

Eq. (2) predicts the traffic *requested from* L2 by the FA3 tiling schedule.
The ground truth here is the cycle simulator's own request counter (the
paper uses NCU on GB10); the bench verifies the closed form tracks the
simulated demand across models x sequence lengths, including the
O(L*S/T_M) long-sequence scaling.
"""
from __future__ import annotations

from repro.configs.llama3 import workload
from repro.core import analytical
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3
from repro.core.tracegen_fa3 import FA3Tiling

from benchmarks.common import Sink, mape, max_ape

MODELS = ("8B", "70B", "405B")
SEQLENS = (512, 1024, 2048)
TILING = FA3Tiling()


def run(sink: Sink):
    cfg = H800
    pairs = []
    scaling = {}
    for m in MODELS:
        for s in SEQLENS:
            w = workload(m, s, batch=1)
            sim = simulate_fa3(w, cfg, fidelity="auto")
            model_bytes = analytical.l2_traffic(w, TILING.t_m)
            pairs.append((model_bytes, sim.l2_bytes))
            scaling[(m, s)] = model_bytes
            sink.row(model=m, seqlen=s,
                     model_l2_gb=round(model_bytes / 1e9, 3),
                     sim_l2_gb=round(sim.l2_bytes / 1e9, 3),
                     lrc_filter=round(sim.l2_delivered_bytes
                                      / max(sim.l2_bytes, 1), 3),
                     ape=round(abs(model_bytes - sim.l2_bytes)
                               / max(sim.l2_bytes, 1), 4),
                     fidelity=sim.fidelity)

    # long-sequence scaling exponent: L2 ~ O(L*S) at L=S -> slope ~2 in log
    import math
    xs = [math.log(s) for s in SEQLENS]
    for m in MODELS:
        ys = [math.log(scaling[(m, s)]) for s in SEQLENS]
        n = len(xs)
        slope = ((n * sum(x * y for x, y in zip(xs, ys)) - sum(xs) * sum(ys))
                 / (n * sum(x * x for x in xs) - sum(xs) ** 2))
        sink.derive(**{f"scaling_exponent_{m}": round(slope, 3)})

    sink.derive(mape_model_vs_sim=round(mape(pairs), 4),
                max_ape=round(max_ape(pairs), 4))
