"""Engine wall-clock throughput benchmark — the perf-trajectory baseline.

Measures how fast the cycle engine *simulates* (not what it predicts):
wall seconds, simulated cycles/s and executed events/s on small / medium /
full-fidelity FA3 launches for the default event-driven scheduler, and —
on the full workload — the waiter and legacy broadcast fallbacks plus the
tile-granular memory fidelity mode (``mem_fidelity="tile"``), so the
speedup each scheduler generation buys stays measurable forever.  Rows
carry ``mem_fidelity``; the smoke gate only ever compares rows of the
same memory fidelity (tile rows time differently from line-exact rows).

    PYTHONPATH=src:. python benchmarks/bench_engine.py            # full run
    PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke    # CI guard
    PYTHONPATH=src:. python benchmarks/bench_engine.py --profile  # cProfile

A standalone full run rewrites ``BENCH_engine.json`` at the repo root
(committed: the baseline subsequent PRs are held to) plus the usual
``results/bench/engine.json``; via ``benchmarks/run.py`` only the latter is
written, so sweeping all benches never clobbers the committed baseline.
The committed baseline is *trajectory-aware*: every standalone full run
appends a dated, git-sha-stamped summary row to its ``history`` list (the
current ``rows``/``derived`` are replaced; history only grows), so the
engine's throughput over the life of the repo stays inspectable.

``--smoke`` runs the tiny workload only and gates **two-sided** against the
committed baseline's smoke row: simulated cycle count must match exactly
(correctness side) and cycles/s must be neither far below the baseline
(perf regression) nor absurdly above it (the workload stopped simulating
what it used to).  The perf floor is *like-for-like*: when the run's
manifest matches the committed baseline's host fingerprint, the counters-
off floor tightens to ``SMOKE_STRICT_MIN_RATIO`` (the observability layer
must stay near-zero-cost when off); on a different host only the wide
legacy band applies — absolute cycles/s across unlike hosts gate nothing.
``--smoke`` also reruns the tiny workload with the PM-counter sink + event
tracer attached, asserts bit-identical simulation, and (with
``--trace-out``) exports the reference Perfetto trace CI uploads as an
artifact.  It writes nothing else.
"""
from __future__ import annotations

import datetime
import json
import math
import subprocess
import time
from pathlib import Path

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import H800
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas
from repro.obs import (CounterSink, build_manifest, export_trace, same_host,
                       subsystem_wall_breakdown)

from benchmarks.common import Sink, maybe_profile

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE_PATH = REPO_ROOT / "BENCH_engine.json"

WORKLOADS = {
    # name -> AttnWorkload; all run at fidelity "full" (every CTA, all SMs)
    "smoke": AttnWorkload(name="smoke", B=1, L=128, S=256, H_kv=1, G=1,
                          D=128),
    "small": AttnWorkload(name="small", B=1, L=256, S=512, H_kv=1, G=2,
                          D=128),
    "medium": AttnWorkload(name="medium", B=1, L=512, S=1024, H_kv=2, G=2,
                           D=128),
    # the reference full-fidelity FA3 launch (same as bench_whatif)
    "full": AttnWorkload(name="full", B=1, L=1024, S=2048, H_kv=2, G=2,
                         D=128),
}

ROW_SCHEMA = ("workload", "wall_s", "sim_cycles", "cycles_per_s",
              "events_per_s")

# Two-sided smoke gate vs. the committed baseline's smoke row: fail when
# cycles/s drop below MIN_RATIO x baseline (perf regression; generous to
# absorb CI-runner jitter) or exceed MAX_RATIO x baseline (a speedup that
# large means the simulated workload shrank, not that the engine got fast).
SMOKE_MIN_RATIO = 0.4
SMOKE_MAX_RATIO = 8.0
# Like-for-like floor: when the manifest host fingerprint matches the
# committed baseline's, the counters-off run must stay within 5% of the
# recorded cycles/s — the observability hooks' "near-zero-cost when off"
# contract, actually enforced.  Never applied across unlike hosts.
SMOKE_STRICT_MIN_RATIO = 0.95

# One-time measurement of the pre-refactor (PR<4) broadcast engine on the
# "full" workload, taken on the baseline machine when this bench was
# introduced: wall median of 3 runs.  Only meaningful relative to wall
# times measured on that machine; the re-measurable comparators on any
# machine are the waiter/broadcast rows below.
PRE_REFACTOR_FULL_WALL_S = 18.8

# stats keys every scheduler must agree on bit-exactly
EQUIV_KEYS = ("sim_cycles", "dram_bytes", "l2_req_bytes", "tma_lines")


def _measure(w: AttnWorkload, scheduler: str = "event",
             counters=None, tracer=None, repeats: int = 1,
             mem_fidelity: str = "line") -> dict:
    """One benchmark row.  ``repeats > 1`` re-runs the simulation on fresh
    engines and keeps the fastest wall time — the smoke workload is ~30 ms,
    where single-shot CPython jitter swamps the 5% strict gate; best-of-N
    maxima are stable enough to compare across runs on the same host."""
    cfg = H800
    tiling = FA3Tiling()
    total = w.B * w.H_kv * w.G * math.ceil(w.L / tiling.t_m)
    ctas, tmaps = fa3_kernel_ctas(
        cfg, B=w.B, H_kv=w.H_kv, G=w.G, L=w.L, S=w.S, D=w.D, tiling=tiling,
        causal=w.causal, max_ctas=total)
    wall = math.inf
    for _ in range(max(1, repeats)):
        if counters is not None:
            counters.__init__(window=counters.window)   # fresh sample series
        if tracer is not None:
            tracer.__init__()
        eng = Engine(cfg, scheduler=scheduler, counters=counters,
                     tracer=tracer, mem_fidelity=mem_fidelity)
        for tm in tmaps.values():
            eng.define_tmap(tm)
        t0 = time.perf_counter()
        eng.launch(ctas)
        st = eng.run()
        wall = min(wall, time.perf_counter() - t0)
    return {
        "workload": w.name,
        "wall_s": round(wall, 4),
        "sim_cycles": st["cycles"],
        "cycles_per_s": round(st["cycles"] / wall, 1),
        "events_per_s": round(eng.evq.popped / wall, 1),
        "n_ctas": len(ctas),
        "scheduler": scheduler,
        "mem_fidelity": mem_fidelity,
        "counters": counters is not None,
        "dram_bytes": st["dram_bytes"],
        "l2_req_bytes": st["l2_req_bytes"],
        "tma_lines": st["tma_lines"],
        "manifest": build_manifest(
            machine=cfg, workload=w, kernel="fa3", tiling=tiling,
            scheduler=scheduler, mem_fidelity=mem_fidelity,
            wall_s=wall, sim_cycles=st["cycles"],
            events_popped=eng.evq.popped,
            counter_window=counters.window if counters is not None else None),
    }


def validate_row(row: dict) -> None:
    """The committed-baseline schema every row must carry."""
    for key in ROW_SCHEMA:
        assert key in row, f"BENCH_engine row missing {key!r}: {row}"
    assert row["wall_s"] > 0 and row["sim_cycles"] > 0
    assert row["cycles_per_s"] > 0 and row["events_per_s"] > 0


def load_baseline() -> dict:
    if BASELINE_PATH.exists():
        return json.loads(BASELINE_PATH.read_text())
    return {}


def smoke_gate(row: dict, baseline: dict, remeasure=None) -> None:
    """Two-sided CI gate: exact simulated-cycle match + bounded cycles/s
    ratio vs. the committed baseline's smoke row.

    The perf floor compares like-for-like: the strict counters-off
    ``SMOKE_STRICT_MIN_RATIO`` floor applies only when this run's manifest
    host fingerprint equals the committed baseline row's (same host class,
    rates comparable); otherwise — unlike host, counters-on run, or a
    pre-manifest legacy baseline — only the wide [MIN, MAX] band gates.

    A strict-floor miss is retried through ``remeasure`` (a fresh
    best-of-N measurement, taken after a pause) before failing: shared CI
    hosts have multi-second CPU-contention phases that depress any single
    wall-clock sample far more than 5%, while a real hook-cost regression
    reproduces on every retry."""
    # memory fidelities time differently (tile collapses per-line events
    # into bulk transactions): a row only ever gates against a committed
    # row of the *same* mem_fidelity — never like-for-like across modes.
    # Rows predating the field are line-exact by construction.
    mf = row.get("mem_fidelity") or "line"
    base_row = next((r for r in baseline.get("rows", [])
                     if r.get("workload") == "smoke"
                     and not r.get("counters")
                     and (r.get("mem_fidelity") or "line") == mf), None)
    if base_row is None:
        return      # no committed smoke row yet: schema validation only
    for attempt in range(3):
        assert row["sim_cycles"] == base_row["sim_cycles"], (
            f"smoke sim_cycles drifted: {row['sim_cycles']} != committed "
            f"{base_row['sim_cycles']} — the engine changed behavior")
        ratio = row["cycles_per_s"] / base_row["cycles_per_s"]
        like_for_like = (not row.get("counters")
                         and same_host(row.get("manifest"),
                                       base_row.get("manifest")))
        floor = SMOKE_STRICT_MIN_RATIO if like_for_like \
            else SMOKE_MIN_RATIO
        if ratio >= floor or remeasure is None or not like_for_like \
                or attempt == 2:
            break
        time.sleep(1.0)         # escape a transient contention phase
        row = remeasure()
    assert ratio >= floor, (
        f"engine throughput regression: smoke cycles/s at {ratio:.2f}x of "
        f"committed baseline ({row['cycles_per_s']:.0f} vs "
        f"{base_row['cycles_per_s']:.0f}; floor {floor}x"
        + (", like-for-like host" if like_for_like else "") + ")")
    assert ratio <= SMOKE_MAX_RATIO, (
        f"smoke cycles/s at {ratio:.2f}x of committed baseline — too fast "
        f"to be the same simulation (cap {SMOKE_MAX_RATIO}x); re-baseline "
        f"deliberately if this is a real engine speedup")


def run(sink: Sink, smoke: bool = False, profile: bool = False,
        trace_out: str = ""):
    names = ["smoke"] if smoke else ["smoke", "small", "medium", "full"]
    rows = []
    with maybe_profile(profile):
        for name in names:
            # the smoke row feeds the strict 5% gate: best-of-5 on both
            # the baseline-writing and gating sides (see _measure)
            row = _measure(WORKLOADS[name],
                           repeats=5 if name == "smoke" else 1)
            validate_row(row)
            rows.append(row)
            sink.row(**row)
    if smoke:
        # counters-on rerun: the sink must be bit-neutral (identical
        # simulation) and its overhead visible; optionally export the
        # reference Perfetto trace CI keeps as an artifact
        from repro.analysis.events import EventTracer
        off = rows[0]
        snk, tracer = CounterSink(), EventTracer()
        on = _measure(WORKLOADS["smoke"], counters=snk, tracer=tracer,
                      repeats=5)
        validate_row(on)
        for key in EQUIV_KEYS:
            assert off[key] == on[key], (
                f"counter sink is not bit-neutral on {key}: "
                f"{off[key]} != {on[key]}")
        assert len(snk.cycles) > 1, "counter sink never sampled"
        rows.append(on)
        sink.row(**on)
        sink.derive(counters_overhead_pct=round(
            100.0 * (off["cycles_per_s"] / on["cycles_per_s"] - 1.0), 1))
        if trace_out:
            export_trace(trace_out, tracer, snk, on["manifest"],
                         name="bench-engine smoke (fa3)")
            print(f"  reference trace written: {trace_out}", flush=True)
    if not smoke:
        # waiter + broadcast fallbacks on the reference launch: each
        # scheduler generation's speedup, re-measurable on any machine
        event = next(r for r in rows if r["workload"] == "full")
        comparators = []
        for sched in ("waiter", "broadcast"):
            c = _measure(WORKLOADS["full"], scheduler=sched)
            comparators.append(c)
            sink.row(**c)
            for key in EQUIV_KEYS:
                assert event[key] == c[key], (
                    f"scheduler equivalence broken on {key} (event vs "
                    f"{sched}): {event[key]} != {c[key]}")
        waiter, broadcast = comparators
        # tile-granular memory fidelity on the reference launch: whole-tile
        # bulk transactions instead of per-line events.  Traffic must stay
        # byte-identical on the exact counters (dram_bytes, tma_lines);
        # cycles and l2_req_bytes are approximated within documented bounds
        # (docs/fidelity.md) — best-of-3 because the run is ~0.2 s.
        tile = _measure(WORKLOADS["full"], mem_fidelity="tile", repeats=3)
        sink.row(**tile)
        rows.append(tile)
        for key in ("dram_bytes", "tma_lines"):
            assert event[key] == tile[key], (
                f"tile fidelity traffic drifted on {key}: "
                f"{event[key]} != {tile[key]}")
        cyc_err = abs(tile["sim_cycles"] / event["sim_cycles"] - 1.0)
        assert cyc_err <= 0.05, (
            f"tile fidelity cycle error {cyc_err:.2%} exceeds 5% bound")
        # host-side wall split by subsystem (cProfile self-time aggregated
        # by module): the reproducible backing for docs/performance.md's
        # "where does the wall go" claims — one profiled full run
        _, breakdown = subsystem_wall_breakdown(_measure, WORKLOADS["full"])
        total_bd = sum(breakdown.values()) or 1.0
        sink.derive(
            speedup_vs_waiter=round(waiter["wall_s"] / event["wall_s"], 2),
            speedup_vs_broadcast=round(
                broadcast["wall_s"] / event["wall_s"], 2),
            speedup_tile_vs_line=round(
                event["wall_s"] / tile["wall_s"], 2),
            tile_cycle_err_pct=round(100.0 * cyc_err, 2),
            speedup_vs_pre_refactor=round(
                PRE_REFACTOR_FULL_WALL_S / event["wall_s"], 2),
            pre_refactor_full_wall_s=PRE_REFACTOR_FULL_WALL_S,
            full_cycles_per_s=event["cycles_per_s"],
            wall_breakdown_full=breakdown,
            wall_breakdown_pct={k: round(100.0 * v / total_bd, 1)
                                for k, v in breakdown.items()},
        )
        rows.extend(comparators)
    return rows


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def write_baseline(sink: Sink, rows: list) -> None:
    """Rewrite the *committed* trajectory baseline, preserving and extending
    its ``history``: the previous runs' summaries stay, this run appends
    one dated/sha-stamped row.  Standalone invocation only —
    ``benchmarks/run.py`` runs must not clobber it in passing."""
    prev = load_baseline()
    history = list(prev.get("history", []))
    if not history and prev.get("rows"):
        # first trajectory-aware run: fold the pre-history committed
        # baseline in as the opening entry so the old numbers survive
        pf = next((r for r in prev["rows"] if r.get("workload") == "full"),
                  None)
        if pf:
            history.append({
                "date": None, "git_sha": "pre-history",
                "full_wall_s": pf.get("wall_s"),
                "full_cycles_per_s": pf.get("cycles_per_s"),
                "scheduler": pf.get("scheduler", "waiter"),
                **{k: v for k, v in prev.get("derived", {}).items()
                   if k.startswith("speedup_")},
            })
    full = next((r for r in rows if r["workload"] == "full"
                 and r["scheduler"] == "event"
                 and (r.get("mem_fidelity") or "line") == "line"), None)
    entry = {
        "date": datetime.date.today().isoformat(),
        "git_sha": _git_sha(),
        "full_wall_s": full["wall_s"] if full else None,
        "full_cycles_per_s": full["cycles_per_s"] if full else None,
        "scheduler": "event",
        **{k: v for k, v in sink.derived.items()
           if k.startswith("speedup_")},
    }
    history.append(entry)
    baseline = {"bench": "engine", "rows": rows, "derived": sink.derived,
                "history": history}
    from repro.utils.ioutil import atomic_write_text
    atomic_write_text(str(BASELINE_PATH), json.dumps(baseline, indent=1) + "\n")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload only (counters off + on); gate vs. "
                         "committed baseline; write nothing")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the simulation and dump the top 20")
    ap.add_argument("--trace-out", default="",
                    help="(--smoke) export the counters-on reference "
                         "Perfetto trace to this path (CI artifact)")
    args = ap.parse_args()

    sink = Sink("engine")
    rows = run(sink, smoke=args.smoke, profile=args.profile,
               trace_out=args.trace_out)
    if not args.smoke:
        sink.finish()
        write_baseline(sink, rows)
        print(f"baseline written: {BASELINE_PATH}")
        print(sink.derived)
    else:
        # CI guard: completed + schema-valid + two-sided baseline gate
        # (strict like-for-like floor on the counters-off row, with
        # contention-phase retries)
        baseline = load_baseline()
        for row in rows:
            validate_row(row)
            remeasure = None
            if not row.get("counters"):
                remeasure = lambda: _measure(WORKLOADS["smoke"], repeats=5)
            smoke_gate(row, baseline, remeasure=remeasure)
        print("smoke ok:", json.dumps(rows))
    sys.exit(0)
