"""Engine wall-clock throughput benchmark — the perf-trajectory baseline.

Measures how fast the cycle engine *simulates* (not what it predicts):
wall seconds, simulated cycles/s and executed events/s on small / medium /
full-fidelity FA3 launches, for the default waiter-indexed scheduler and —
on the full workload — the legacy broadcast fallback, so the speedup the
waiter scheduler buys stays measurable forever.

    PYTHONPATH=src:. python benchmarks/bench_engine.py            # full run
    PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke    # CI guard
    PYTHONPATH=src:. python benchmarks/bench_engine.py --profile  # cProfile

A standalone full run rewrites ``BENCH_engine.json`` at the repo root
(committed: the baseline subsequent PRs are held to) plus the usual
``results/bench/engine.json``; via ``benchmarks/run.py`` only the latter is
written, so sweeping all benches never clobbers the committed baseline.
``--smoke`` runs the tiny workload only, validates the JSON schema, and
writes nothing at the repo root.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.machine import H800
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas

from benchmarks.common import Sink, maybe_profile

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE_PATH = REPO_ROOT / "BENCH_engine.json"

WORKLOADS = {
    # name -> AttnWorkload; all run at fidelity "full" (every CTA, all SMs)
    "smoke": AttnWorkload(name="smoke", B=1, L=128, S=256, H_kv=1, G=1,
                          D=128),
    "small": AttnWorkload(name="small", B=1, L=256, S=512, H_kv=1, G=2,
                          D=128),
    "medium": AttnWorkload(name="medium", B=1, L=512, S=1024, H_kv=2, G=2,
                           D=128),
    # the reference full-fidelity FA3 launch (same as bench_whatif)
    "full": AttnWorkload(name="full", B=1, L=1024, S=2048, H_kv=2, G=2,
                         D=128),
}

ROW_SCHEMA = ("workload", "wall_s", "sim_cycles", "cycles_per_s",
              "events_per_s")

# One-time measurement of the pre-refactor (PR<4) broadcast engine on the
# "full" workload, taken on the baseline machine when this bench was
# introduced: wall median of 3 runs.  Only meaningful relative to wall
# times measured on that machine; the re-measurable comparator on any
# machine is the broadcast-fallback row below.
PRE_REFACTOR_FULL_WALL_S = 18.8


def _measure(w: AttnWorkload, broadcast: bool = False) -> dict:
    cfg = H800
    tiling = FA3Tiling()
    total = w.B * w.H_kv * w.G * math.ceil(w.L / tiling.t_m)
    ctas, tmaps = fa3_kernel_ctas(
        cfg, B=w.B, H_kv=w.H_kv, G=w.G, L=w.L, S=w.S, D=w.D, tiling=tiling,
        causal=w.causal, max_ctas=total)
    eng = Engine(cfg, broadcast_wake=broadcast)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    t0 = time.perf_counter()
    eng.launch(ctas)
    st = eng.run()
    wall = time.perf_counter() - t0
    return {
        "workload": w.name,
        "wall_s": round(wall, 4),
        "sim_cycles": st["cycles"],
        "cycles_per_s": round(st["cycles"] / wall, 1),
        "events_per_s": round(eng.evq.popped / wall, 1),
        "n_ctas": len(ctas),
        "scheduler": "broadcast" if broadcast else "waiter",
        "dram_bytes": st["dram_bytes"],
        "l2_req_bytes": st["l2_req_bytes"],
        "tma_lines": st["tma_lines"],
    }


def validate_row(row: dict) -> None:
    """The committed-baseline schema every row must carry."""
    for key in ROW_SCHEMA:
        assert key in row, f"BENCH_engine row missing {key!r}: {row}"
    assert row["wall_s"] > 0 and row["sim_cycles"] > 0
    assert row["cycles_per_s"] > 0 and row["events_per_s"] > 0


def run(sink: Sink, smoke: bool = False, profile: bool = False):
    names = ["smoke"] if smoke else ["small", "medium", "full"]
    rows = []
    with maybe_profile(profile):
        for name in names:
            row = _measure(WORKLOADS[name])
            validate_row(row)
            rows.append(row)
            sink.row(**row)
    if not smoke:
        # broadcast fallback on the reference launch: the waiter scheduler's
        # speedup, re-measurable on any machine
        b = _measure(WORKLOADS["full"], broadcast=True)
        sink.row(**b)
        waiter = next(r for r in rows if r["workload"] == "full")
        for key in ("sim_cycles", "dram_bytes", "l2_req_bytes", "tma_lines"):
            assert waiter[key] == b[key], \
                f"scheduler equivalence broken on {key}: {waiter[key]} != {b[key]}"
        sink.derive(
            speedup_vs_broadcast=round(b["wall_s"] / waiter["wall_s"], 2),
            speedup_vs_pre_refactor=round(
                PRE_REFACTOR_FULL_WALL_S / waiter["wall_s"], 2),
            pre_refactor_full_wall_s=PRE_REFACTOR_FULL_WALL_S,
            full_cycles_per_s=waiter["cycles_per_s"],
        )
    return rows


def write_baseline(sink: Sink, rows: list) -> None:
    """Overwrite the *committed* trajectory baseline.  Standalone invocation
    only — ``benchmarks/run.py`` runs must not clobber it in passing."""
    baseline = {"bench": "engine", "rows": rows, "derived": sink.derived}
    BASELINE_PATH.write_text(json.dumps(baseline, indent=1) + "\n")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload only; validate schema; write nothing")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the simulation and dump the top 20")
    args = ap.parse_args()

    sink = Sink("engine")
    rows = run(sink, smoke=args.smoke, profile=args.profile)
    if not args.smoke:
        sink.finish()
        write_baseline(sink, rows)
        print(f"baseline written: {BASELINE_PATH}")
        print(sink.derived)
    else:
        # CI guard: completed + schema-valid is the contract
        for row in rows:
            validate_row(row)
        print("smoke ok:", json.dumps(rows))
    sys.exit(0)
