"""Paper Fig. 9 — DRAM traffic regimes: SimFA-python vs GenZ vs simulation.

Llama-3 405B, B=1, growing sequence length. Three curves:
  * GenZ-style ideal-cache baseline (Q/K/V/O moved once) — the paper shows
    it *under*-estimates long sequences;
  * SimFA-python with the Eq.-4 regime split and Eq.-5/6 wave model;
  * the cycle simulator's measured DRAM bytes (hierarchical fidelity, memory
    system scaled with the simulated SM subset).

The reproduced claim: measured traffic leaves the ideal regime once the K/V
working set exceeds the effective LLC capacity, and the wave model tracks it
while the ideal model diverges. The simulated machine's capacity boundary
sits at S* where 2*P*S*D = effective L2 of the *scaled* memory system, so
the crossover happens at proportionally shorter S than H800's 32-48K.
"""
from __future__ import annotations

from repro.configs.llama3 import workload
from repro.core import analytical
from repro.core.genz_baseline import genz_dram_traffic
from repro.core.machine import H800, h800_variant
from repro.core.simfa import simulate_fa3
from repro.core.tracegen_fa3 import FA3Tiling

from benchmarks.common import Sink

# the simulated sub-machine's Eq.-4 boundary sits at S* = L2_eff/(2*P*D)
# ~ 3K for the 8/132-scaled L2 — the regime transition is fully visible
# inside this (cheap) range; the H800-scale 32-48K crossover is validated
# analytically in tests/test_analytical.py
SEQLENS = (1024, 2048, 4096, 8192, 12288)
N_SUB = 8
TILING = FA3Tiling()


def run(sink: Sink):
    cfg = H800
    # scaled-memory analytical twin of the simulated sub-machine: N_SUB SMs
    # with an L2/DRAM share of N_SUB/132 — the hierarchical-fidelity deal
    scale = N_SUB / cfg.num_sms
    sub = h800_variant(num_sms=N_SUB,
                       l2_bytes=int(cfg.l2_bytes * scale),
                       dram_bw_gbps=cfg.dram_bw_gbps * scale,
                       dram_channels=max(1, int(cfg.dram_channels * scale)))

    ideal_exits = None
    for s in SEQLENS:
        w = workload("405B", s, batch=1)
        sim = simulate_fa3(w, cfg, fidelity="hierarchical", n_sub=N_SUB)
        # per-CTA traffic from the sub-machine, extrapolated to the launch —
        # compare against the sub-machine analytical model scaled the same way
        rep = analytical.analyze(w, sub, t_m=TILING.t_m)
        genz_b = genz_dram_traffic(w)
        measured = sim.dram_bytes
        if not rep.ideal_regime and ideal_exits is None:
            ideal_exits = s
        sink.row(seqlen=s,
                 measured_gb=round(measured / 1e9, 3),
                 simfa_gb=round(rep.dram_bytes / 1e9, 3),
                 genz_ideal_gb=round(genz_b / 1e9, 3),
                 regime="ideal" if rep.ideal_regime else "realistic",
                 waves=rep.waves_per_group,
                 ape_simfa=round(abs(rep.dram_bytes - measured)
                                 / max(measured, 1), 3),
                 ape_genz=round(abs(genz_b - measured) / max(measured, 1), 3))

    rows = sink.rows
    last = rows[-1]
    first = rows[0]
    sink.derive(
        regime_transition_seqlen=ideal_exits,
        genz_underestimates_long=last["genz_ideal_gb"] < 0.6 * last["measured_gb"],
        simfa_tracks_long=last["ape_simfa"] < 0.5,
        short_seq_near_ideal=first["ape_genz"] < 0.6,
        note=("crossover scaled to the simulated sub-machine's L2 share; "
              "H800-scale crossover at 32-48K reproduced analytically in "
              "tests/test_analytical.py"),
    )
