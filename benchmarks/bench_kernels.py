"""Kernel microbench: Pallas flash attention / flash decode (interpret mode)
vs the pure-jnp oracles — correctness deltas + CPU wall time per call.

Wall time in interpret mode is NOT a TPU performance proxy; the performance
artifact for kernels is the roofline/§Perf analysis. This bench pins down
numerical parity and gives a regression-visible latency fingerprint.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode

from benchmarks.common import Sink

KEY = jax.random.PRNGKey(0)


def _time(fn, *a, n=3, **kw):
    fn(*a, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a, **kw)
    out.block_until_ready()
    return out, (time.perf_counter() - t0) / n * 1e6


def run(sink: Sink):
    cases = [
        ("fwd_256x64", dict(B=1, H=4, Hkv=2, L=256, S=256, D=64, causal=True)),
        ("fwd_128x128", dict(B=2, H=4, Hkv=4, L=128, S=128, D=128, causal=False)),
    ]
    for name, c in cases:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (c["B"], c["H"], c["L"], c["D"]))
        k = jax.random.normal(ks[1], (c["B"], c["Hkv"], c["S"], c["D"]))
        v = jax.random.normal(ks[2], (c["B"], c["Hkv"], c["S"], c["D"]))
        o, t_k = _time(flash_attention, q, k, v, causal=c["causal"],
                       block_q=64, block_k=64, interpret=True)
        o_ref, t_r = _time(ref.flash_attention_ref, q, k, v, causal=c["causal"])
        err = float(jnp.max(jnp.abs(o - o_ref)))
        sink.row(case=name, us_per_call=round(t_k, 1),
                 ref_us=round(t_r, 1), max_abs_err=err)
        assert err < 2e-5, f"{name}: kernel diverges from oracle"

    # decode
    B, H, Hkv, S, D = 2, 8, 2, 512, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, Hkv, S, D))
    vc = jax.random.normal(ks[2], (B, Hkv, S, D))
    o, t_k = _time(flash_decode, q, kc, vc, 300, block_k=128, interpret=True)
    o_ref, t_r = _time(ref.flash_decode_ref, q, kc, vc, jnp.full((B,), 300))
    err = float(jnp.max(jnp.abs(o - o_ref)))
    sink.row(case="decode_512", us_per_call=round(t_k, 1), ref_us=round(t_r, 1),
             max_abs_err=err)
    assert err < 2e-5
    sink.derive(all_match_oracle=True)
