"""Kernel benchmarks, two halves:

1. **Registered kernel-program scenarios** (cycle engine, no jax): every
   kernel in the ``repro.core.kprog`` registry — fa3 ping-pong, fa3
   cooperative, fa2 non-specialized, split-KV decode — simulated at full
   fidelity on a scenario-sized workload; reports predicted cycles and the
   engine's simulation throughput (cycles/s).  Also the CI smoke guard:
   ``--smoke`` runs the fa3 scenario only and compares its cycles/s
   against the committed ``BENCH_engine.json`` trajectory baseline with a
   generous 30% regression tolerance.
2. **Pallas microbench** (interpret mode): flash attention / flash decode
   vs the pure-jnp oracles — correctness deltas + CPU wall time per call.
   Wall time in interpret mode is NOT a TPU performance proxy; this half
   pins numerical parity.

    PYTHONPATH=src:. python benchmarks/bench_kernels.py            # both
    PYTHONPATH=src:. python benchmarks/bench_kernels.py --smoke    # CI guard
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import Sink

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE_PATH = REPO_ROOT / "BENCH_engine.json"

# 30%: generous enough for runner-to-runner jitter, tight enough that an
# accidentally quadratic lowering or scheduler regression trips it
SMOKE_REL_TOL = 0.30

# scenario workloads sized so full fidelity stays in CI budget
SCENARIOS = {
    # kernel -> AttnWorkload kwargs
    "fa3": dict(B=1, L=256, S=1024, H_kv=1, G=2, D=128),
    "fa3_cooperative": dict(B=1, L=256, S=1024, H_kv=1, G=2, D=128),
    "fa2": dict(B=1, L=256, S=1024, H_kv=1, G=2, D=128),
    "splitkv_decode": dict(B=2, L=1, S=4096, H_kv=2, G=4, D=128),
}


def _simulate_scenario(kernel: str) -> dict:
    from repro.configs.llama3 import AttnWorkload
    from repro.core.machine import H800
    from repro.core.simfa import simulate_fa3

    w = AttnWorkload(name=kernel, **SCENARIOS[kernel])
    t0 = time.perf_counter()
    res = simulate_fa3(w, H800, fidelity="full", kernel=kernel)
    wall = time.perf_counter() - t0
    assert not res.deadlocked, f"{kernel}: deadlocked"
    return {
        "scenario": kernel,
        "sim_cycles": int(res.cycles),
        "latency_us": round(res.latency_us, 2),
        "tc_util": round(res.tc_util, 4),
        "l2_bytes": int(res.l2_bytes),
        "dram_bytes": int(res.dram_bytes),
        "n_ctas": res.n_ctas_total,
        "wall_s": round(wall, 4),
        "cycles_per_s": round(res.cycles / max(wall, 1e-9), 1),
    }


def _smoke_measure(n_reps: int = 3) -> dict:
    """CI guard measurement: the fa3 "small" workload (the same one the
    committed baseline's "small" row measures) through the IR, timing the
    same window ``bench_engine._measure`` times — ``launch``+``run`` only,
    traces built and builtins imported *outside* the timer — best of
    ``n_reps`` so transient runner load doesn't trip the gate."""
    from repro.configs.llama3 import AttnWorkload
    from repro.core.engine import Engine
    from repro.core.kprog import registry
    from repro.core.machine import H800

    kw = dict(SCENARIOS["fa3"])
    kw["S"] = min(kw["S"], 512)             # == BENCH_engine "small"
    w = AttnWorkload(name="fa3_smoke", **kw)
    spec = registry.get("fa3")
    best = None
    for _ in range(n_reps):
        ctas, tmaps = spec.build(H800, w)
        eng = Engine(H800)
        for tm in tmaps.values():
            eng.define_tmap(tm)
        t0 = time.perf_counter()
        eng.launch(ctas)
        st = eng.run()
        wall = time.perf_counter() - t0
        assert not eng.deadlocked
        row = {
            "scenario": "fa3", "sim_cycles": st["cycles"],
            "n_ctas": len(ctas), "wall_s": round(wall, 4),
            "cycles_per_s": round(st["cycles"] / max(wall, 1e-9), 1),
        }
        if best is None or row["wall_s"] < best["wall_s"]:
            best = row
    return best


def run_scenarios(sink: Sink) -> list:
    rows = [_simulate_scenario(k) for k in SCENARIOS]
    for row in rows:
        sink.row(**row)
    by = {r["scenario"]: r for r in rows}
    sink.derive(
        n_scenarios=len(rows),
        coop_over_pingpong=round(by["fa3_cooperative"]["sim_cycles"]
                                 / by["fa3"]["sim_cycles"], 4),
        fa2_over_fa3=round(by["fa2"]["sim_cycles"]
                           / by["fa3"]["sim_cycles"], 4),
    )
    return rows


def check_against_baseline(cycles_per_s: float,
                           rel_tol: float = SMOKE_REL_TOL) -> dict:
    """Compare measured engine throughput against the committed
    ``BENCH_engine.json`` baseline (the "small" row is the closest match
    for the smoke workload).  Fails only on a regression beyond
    ``rel_tol`` below the baseline — faster is always fine."""
    baseline = json.loads(BASELINE_PATH.read_text())
    ref = next(r for r in baseline["rows"] if r["workload"] == "small")
    floor = ref["cycles_per_s"] * (1.0 - rel_tol)
    ok = cycles_per_s >= floor
    return {"measured": cycles_per_s, "baseline": ref["cycles_per_s"],
            "floor": round(floor, 1), "ok": ok}


def run_pallas(sink: Sink) -> None:
    """Pallas interpret-mode kernels vs jnp oracles (jax imported lazily so
    the cycle-engine half never pays for it)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_decode import flash_decode

    key = jax.random.PRNGKey(0)

    def _time(fn, *a, n=3, **kw):
        fn(*a, **kw).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(*a, **kw)
        out.block_until_ready()
        return out, (time.perf_counter() - t0) / n * 1e6

    cases = [
        ("fwd_256x64", dict(B=1, H=4, Hkv=2, L=256, S=256, D=64, causal=True)),
        ("fwd_128x128", dict(B=2, H=4, Hkv=4, L=128, S=128, D=128, causal=False)),
    ]
    for name, c in cases:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (c["B"], c["H"], c["L"], c["D"]))
        k = jax.random.normal(ks[1], (c["B"], c["Hkv"], c["S"], c["D"]))
        v = jax.random.normal(ks[2], (c["B"], c["Hkv"], c["S"], c["D"]))
        o, t_k = _time(flash_attention, q, k, v, causal=c["causal"],
                       block_q=64, block_k=64, interpret=True)
        o_ref, t_r = _time(ref.flash_attention_ref, q, k, v, causal=c["causal"])
        err = float(jnp.max(jnp.abs(o - o_ref)))
        sink.row(case=name, us_per_call=round(t_k, 1),
                 ref_us=round(t_r, 1), max_abs_err=err)
        assert err < 2e-5, f"{name}: kernel diverges from oracle"

    # decode
    B, H, Hkv, S, D = 2, 8, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, Hkv, S, D))
    vc = jax.random.normal(ks[2], (B, Hkv, S, D))
    o, t_k = _time(flash_decode, q, kc, vc, 300, block_k=128, interpret=True)
    o_ref, t_r = _time(ref.flash_decode_ref, q, kc, vc, jnp.full((B,), 300))
    err = float(jnp.max(jnp.abs(o - o_ref)))
    sink.row(case="decode_512", us_per_call=round(t_k, 1), ref_us=round(t_r, 1),
             max_abs_err=err)
    assert err < 2e-5
    sink.derive(all_match_oracle=True)


def run(sink: Sink):
    run_scenarios(sink)
    run_pallas(sink)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fa3 scenario only (tiny S), check cycles/s "
                         "against the committed BENCH_engine.json baseline "
                         f"with {SMOKE_REL_TOL:.0%} regression tolerance")
    args = ap.parse_args()

    if args.smoke:
        row = _smoke_measure()
        chk = check_against_baseline(row["cycles_per_s"])
        print("smoke:", json.dumps({**row, "baseline_check": chk}))
        if not chk["ok"]:
            print(f"ENGINE THROUGHPUT REGRESSION: {chk['measured']} "
                  f"cycles/s < floor {chk['floor']} "
                  f"(baseline {chk['baseline']}, tol {SMOKE_REL_TOL:.0%})")
            sys.exit(1)
        sys.exit(0)

    sink = Sink("kernels")
    run(sink)
    out = sink.finish()
    print(json.dumps(out["derived"], indent=1))
    sys.exit(0)
