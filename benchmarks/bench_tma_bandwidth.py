"""Paper Fig. 5 — TMA bandwidth for bulk / 1D / 2D / 3D TensorMap copies.

Every SM runs one producer WarpGroup streaming tile loads over a working set
far larger than L2 (miss-dominated). Achieved *payload* bandwidth is
``payload_bytes / wall_cycles``; box shapes whose inner extent is not a
multiple of the 128 B line overfetch and land below the HBM roofline —
the shape-dependent spread the paper measures on H800.
"""
from __future__ import annotations

import math
from typing import List

from repro.core import isa
from repro.core.engine import CTATrace, Engine
from repro.core.isa import Instr, TensorMap
from repro.core.machine import H800, GPUMachine

from benchmarks.common import Sink

GiB = 1024 ** 3


def _copy_cta(n_tiles: int, map_id: int, box_rows: int, tile_stride_rows: int,
              bulk: bool) -> CTATrace:
    """One producer WG issuing n_tiles loads, then waiting for all."""
    tr: List[Instr] = []
    for j in range(n_tiles):
        tr.append(Instr(isa.TMA_TENSOR, map_id=map_id, sid=j,
                        origin=(0, j * tile_stride_rows, 0), bulk=bulk,
                        tag=f"t{j}"))
    for j in range(n_tiles):
        tr.append(Instr(isa.MB_WAIT, sid=j))
    return CTATrace(wgs=[tr], n_consumers=1, name="copy")


def bandwidth_case(cfg: GPUMachine, *, name: str, box, dims, strides, esz=2,
                   bulk=False, n_sms=132, tiles_per_sm=16):
    """Run one Fig.-5 copy case; returns payload GB/s and efficiency."""
    payload_tile = esz * math.prod(box)
    eng = Engine(cfg, n_sms=n_sms, mem_scale=1.0)
    ctas = []
    for sm in range(n_sms):
        # disjoint address spaces per SM: no cross-SM reuse
        base = sm * (1 << 33)
        tm = TensorMap(sm, base, dims, strides, box, esz)
        eng.define_tmap(tm)
        ctas.append(_copy_cta(tiles_per_sm, sm, box[-2] if len(box) > 1 else 1,
                              box[1] if len(box) > 2 else (box[0] if len(box) > 1 else 1),
                              bulk))
    eng.launch(ctas)
    st = eng.run()
    payload = payload_tile * tiles_per_sm * n_sms
    secs = st["cycles"] / (cfg.freq_ghz * 1e9)
    gbs = payload / secs / 1e9
    fetched = st["dram_bytes"]
    eff = payload / max(fetched, 1)
    return {"name": name, "payload_gbs": gbs, "dram_gbs": fetched / secs / 1e9,
            "line_efficiency": eff, "cycles": st["cycles"],
            "deadlocked": eng.deadlocked}


# Fig. 5 cases: different TensorMap geometries over huge backing tensors;
# boxes tile the tensor without reuse (miss-dominated, DRAM-bound).
def cases(cfg):
    e = 2
    return [
        # contiguous 64 KiB bulk copy (non-tensor path: no descriptor setup)
        dict(name="bulk", box=(1, 64, 512), dims=(1, 1 << 20, 512),
             strides=(1 << 40, 512 * e, e), bulk=True, tiles_per_sm=6),
        # 1D TensorMap: same geometry through the descriptor path
        dict(name="1d_tmap", box=(1, 64, 512), dims=(1, 1 << 20, 512),
             strides=(1 << 40, 512 * e, e), bulk=False, tiles_per_sm=6),
        # 2D 64x64 fp16 tile = 128 B rows, line-aligned (paper's worst MAPE)
        dict(name="2d_64x64", box=(1, 64, 64), dims=(1, 1 << 20, 64),
             strides=(1 << 40, 64 * e, e), bulk=False, tiles_per_sm=48),
        # 2D 64x48 tile in a 64-wide padded tensor: 96 B payload rows on
        # 128 B line-aligned strides -> 75% line efficiency
        dict(name="2d_64x48", box=(1, 64, 48), dims=(1, 1 << 20, 64),
             strides=(1 << 40, 64 * e, e), bulk=False, tiles_per_sm=48),
        # 3D 8x16x32 box in a 128-wide padded tensor: 64 B inner extent on
        # 256 B strides -> 50% line efficiency
        dict(name="3d_8x16x32", box=(8, 16, 32), dims=(1 << 10, 1 << 10, 128),
             strides=(1 << 30, 128 * e, e), bulk=False, tiles_per_sm=24),
    ]


def run(sink: Sink):
    cfg = H800
    peak = cfg.dram_bw_gbps
    results = {}
    for c in cases(cfg):
        r = bandwidth_case(cfg, **c)
        results[c["name"]] = r
        sink.row(case=r["name"], payload_gbs=round(r["payload_gbs"], 1),
                 dram_gbs=round(r["dram_gbs"], 1),
                 line_eff=round(r["line_efficiency"], 3),
                 frac_of_peak=round(r["payload_gbs"] / peak, 3))
        assert not r["deadlocked"]

    sink.derive(
        hbm_peak_gbs=peak,
        aligned_reaches_peak=results["2d_64x64"]["payload_gbs"] > 0.85 * peak,
        partial_line_penalty=round(
            results["2d_64x48"]["payload_gbs"]
            / results["2d_64x64"]["payload_gbs"], 3),
        inner64B_penalty=round(
            results["3d_8x16x32"]["payload_gbs"]
            / results["2d_64x64"]["payload_gbs"], 3),
        bulk_vs_1d_setup_delta_cycles=(
            results["1d_tmap"]["cycles"] - results["bulk"]["cycles"]),
    )
