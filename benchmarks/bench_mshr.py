"""Paper Fig. 4 — MSHR sensitivity of TMA bandwidth.

Sweeps the per-slice LLC MSHR count on the miss-dominated 2D 64x64 copy
(the paper's most MSHR-visible case). Small pools throttle memory-level
parallelism below the bandwidth-delay product; beyond the knee the curve
flattens — the paper finds the measured H800 sits at the 256 inflection
point. With no hardware, the reproduced artifact is the knee itself: the
calibrated value (256) must lie in the saturated region while 96-or-less
clearly throttles.
"""
from __future__ import annotations

from repro.core.machine import h800_variant

from benchmarks.common import Sink
from benchmarks.bench_mshr_harness import measure_bw_2d

MSHR_SWEEP = [48, 96, 128, 192, 256, 384]


def run(sink: Sink):
    bw = {}
    for mshr in MSHR_SWEEP:
        cfg = h800_variant(l2_mshr_per_slice=mshr)
        r = measure_bw_2d(cfg)
        bw[mshr] = r["payload_gbs"]
        sink.row(mshr_per_slice=mshr, payload_gbs=round(r["payload_gbs"], 1),
                 cycles=r["cycles"])
    peak = max(bw.values())
    knee = min(m for m in MSHR_SWEEP if bw[m] >= 0.97 * peak)
    sink.derive(
        knee_mshr=knee,
        bw_at_knee_gbs=round(bw[knee], 1),
        bw_48_frac=round(bw[48] / peak, 3),
        calibrated_256_saturated=bw[256] >= 0.97 * peak,
        throttled_below_knee=bw[48] < 0.8 * peak,
    )
