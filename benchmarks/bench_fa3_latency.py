"""Paper Fig. 6 — FA3 kernel latency: cycle simulation vs analytical model.

The paper validates Sim-FA against H800 wall-clock over Llama-3 {8B, 70B,
405B} x seqlen {512, 1024, 2048, 4096, 6144} and reports 5.7% MAPE. With no
H800 in this container, the reproduced artifact is *internal consistency*:
the cycle-level pipeline simulation must land near the corrected analytical
model (SimFA-python) across the same 15 cells, and both must sit above the
naive roofline lower bound. Large cells exercise the hierarchical fidelity
fallback exactly as the paper falls back to the analytical model.
"""
from __future__ import annotations

from repro.configs.llama3 import workload
from repro.core import analytical
from repro.core.genz_baseline import genz_latency
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3

from benchmarks.common import Sink, mape, max_ape

MODELS = ("8B", "70B", "405B")
SEQLENS = (512, 1024, 2048, 4096, 6144)


def run(sink: Sink):
    cfg = H800
    pairs = []
    for m in MODELS:
        for s in SEQLENS:
            w = workload(m, s, batch=1)
            sim = simulate_fa3(w, cfg, fidelity="auto")
            rep = analytical.analyze(w, cfg)
            genz_us = genz_latency(w, cfg) * 1e6
            ana_us = rep.latency * 1e6
            pairs.append((sim.latency_us, ana_us))
            sink.row(model=m, seqlen=s, sim_us=round(sim.latency_us, 1),
                     analytical_us=round(ana_us, 1),
                     genz_roofline_us=round(genz_us, 1),
                     fidelity=sim.fidelity,
                     tc_util=round(sim.tc_util, 3),
                     bottleneck=rep.bottleneck,
                     ape=round(abs(sim.latency_us - ana_us) / ana_us, 4))
            assert not sim.deadlocked, f"deadlock at {m}/{s}"

    sink.derive(
        mape_sim_vs_analytical=round(mape(pairs), 4),
        max_ape=round(max_ape(pairs), 4),
        paper_mape=0.057,
        paper_max_ape=0.127,
        note=("no H800 available: reference is the corrected analytical "
              "model, not hardware (DESIGN.md §8)"),
    )
