"""Benchmark suite runner — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME..]] [--fast]

Prints ``name,key=value,...`` CSV rows per benchmark plus a final summary;
writes ``results/bench/<name>.json`` per bench.

| paper artifact                     | bench            |
|------------------------------------|------------------|
| Fig. 3 TMA latency regimes         | tma_latency      |
| Fig. 4 MSHR sensitivity            | mshr             |
| Fig. 5 TMA bandwidth bulk/1D/2D/3D | tma_bandwidth    |
| Fig. 6 FA3 latency sim-vs-model    | fa3_latency      |
| Fig. 7 pipeline Gantt              | gantt            |
| Fig. 8 L2 traffic validation       | traffic_l2       |
| Fig. 9 DRAM regimes vs GenZ        | traffic_dram     |
| Table 5 ablations                  | ablations        |
| (ours) Pallas kernels vs oracle    | kernels          |
| (ours) tile-fidelity error budget  | fidelity         |
| (ours) dry-run roofline terms      | roofline         |
| (ours) variability degradation     | faults           |
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import traceback

from benchmarks.common import Sink, maybe_profile

BENCHES = [
    "kernels",
    "roofline",
    "gantt",
    "ablations",
    "faults",
    "fa3_latency",
    "engine",
    "fidelity",
    "traffic_l2",
    "traffic_dram",
    "tma_latency",
    "mshr",
    "tma_bandwidth",
]

FAST_SKIP = {"tma_bandwidth", "mshr", "tma_latency",   # slowest microbenches
             "engine",   # full-fidelity launch + broadcast-fallback rerun
             "fidelity",  # full reference launch in both memory fidelities
             "faults"}   # 15-point Monte-Carlo sensitivity sweep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slowest microbenches")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each bench and dump the top 20 by "
                         "cumulative time (see benchmarks/common.py)")
    ap.add_argument("--smoke", action="store_true",
                    help="forwarded to benches that accept it (engine: tiny "
                         "workload + counters-on bit-neutrality gate)")
    ap.add_argument("--trace-out", default="",
                    help="forwarded to benches that accept it (engine "
                         "--smoke: reference Perfetto trace path)")
    args = ap.parse_args(argv)

    names = list(BENCHES)
    if args.only:
        names = [n.strip() for n in args.only.split(",")]
    elif args.fast:
        names = [n for n in names if n not in FAST_SKIP]

    failures = []
    summaries = []
    for name in names:
        print(f"=== bench {name} ===", flush=True)
        sink = Sink(name)
        try:
            mod = importlib.import_module(f"benchmarks.bench_{name}")
            # forward opt-in flags only to benches whose run() accepts them
            accepted = inspect.signature(mod.run).parameters
            kw = {k: v for k, v in
                  (("smoke", args.smoke), ("trace_out", args.trace_out))
                  if v and k in accepted}
            with maybe_profile(args.profile):
                mod.run(sink, **kw)
            out = sink.finish()
            summaries.append((name, out["wall_s"], out["derived"]))
            print(f"--- {name} ok ({out['wall_s']}s) "
                  f"derived={out['derived']}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            print(f"--- {name} FAILED: {e}", flush=True)

    print("\n=== summary ===")
    for name, wall, derived in summaries:
        print(f"{name},wall_s={wall}," +
              ",".join(f"{k}={v}" for k, v in derived.items()
                       if not isinstance(v, (dict, list))))
    if failures:
        print(f"\n{len(failures)} bench(es) FAILED: "
              f"{[n for n, _ in failures]}")
        return 1
    print(f"\nall {len(summaries)} benches passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
