"""Paper Fig. 3 — TMA latency across working-set sizes.

Reproduces the random-pointer-chase methodology: serialized single-line TMA
loads over working sets spanning the L2-hit floor (<25 MB), the partitioned
25-50 MB transition window (RemoteCopy proxy active), and the DRAM-bound
plateau (>50 MB). Having no H800, the reference is the paper's *regime
structure*: three latency levels, monotone non-decreasing, with the floor at
near-L2 latency + TMA setup and the plateau adding the DRAM round trip.
"""
from __future__ import annotations

import random

from repro.core.machine import H800, h800_variant
from repro.core.memory import EventQueue, build_memory

from benchmarks.common import Sink

WS_MB = [4, 8, 16, 25, 28, 32, 40, 50, 64, 96, 128]
N_PROBES = 400
SEED = 7


def chase_latency(cfg, ws_bytes: int, seed: int = SEED) -> float:
    """Average latency (cycles) of a serialized random-permutation pointer
    chase over ``ws_bytes``: warm laps bring the system to steady state
    (mirrors populated, LRU settled), then one measured lap."""
    evq = EventQueue()
    lrc, l2, dram = build_memory(cfg, evq)
    rng = random.Random(seed)
    n_lines = ws_bytes // cfg.line_bytes
    setup = cfg.tma_launch_latency + cfg.tma_tmap_setup_latency
    order = list(range(n_lines))
    rng.shuffle(order)

    # warm lap 0: untimed tag inserts (one pass of the chase, no timing)
    for i in order:
        addr = i * cfg.line_bytes
        l2.slices[l2.slice_of(addr)]._insert(addr)

    warm_laps = 2 if ws_bytes <= 50 * 1024 * 1024 else 1
    # cap the measured lap so huge working sets stay tractable
    measure = min(n_lines, 40_000)
    total = warm_laps * n_lines + measure
    state = {"cycle": 0, "done": 0, "lat_sum": 0, "measured": 0}
    current = [0]

    def probe():
        if state["done"] >= total:
            return
        i = state["done"]
        lap_pos = i % n_lines
        addr = order[lap_pos] * cfg.line_bytes
        t_issue = state["cycle"]
        timed = i >= warm_laps * n_lines

        def l2_cb():
            # fires inside pop_ready(nxt): current[0] is the absorb cycle
            if timed:
                state["lat_sum"] += current[0] - t_issue + setup
                state["measured"] += 1
            state["done"] += 1
            state["cycle"] = current[0]
            probe()

        lrc.request(t_issue, addr, 0, l2_cb)

    probe()
    while evq._h and state["done"] < total:  # noqa: SLF001
        nxt = evq.next_cycle()
        current[0] = nxt
        evq.pop_ready(nxt)
    return state["lat_sum"] / max(state["measured"], 1)


def run(sink: Sink):
    cfg = H800
    lat = {}
    for ws in WS_MB:
        cycles = chase_latency(cfg, ws * 1024 * 1024)
        lat[ws] = cycles
        regime = ("l2_floor" if ws < 25 else
                  "transition" if ws <= 50 else "dram_plateau")
        sink.row(ws_mb=ws, avg_cycles=round(cycles, 1), regime=regime)

    # no-RemoteCopy ablation over the transition window (Fig. 3 inset)
    cfg_norc = h800_variant(remote_copy=False)
    for ws in (28, 40):
        cycles = chase_latency(cfg_norc, ws * 1024 * 1024)
        sink.row(ws_mb=ws, avg_cycles=round(cycles, 1), regime="transition_noRC")

    floor = min(lat[w] for w in WS_MB if w < 25)
    plateau = lat[128]
    mid = lat[32]
    setup = cfg.tma_launch_latency + cfg.tma_tmap_setup_latency
    sink.derive(
        floor_cycles=round(floor, 1),
        plateau_cycles=round(plateau, 1),
        setup_cycles=setup,
        floor_expected=setup + cfg.l2_near_latency,
        plateau_gt_mid_gt_floor=bool(plateau > mid > floor),
        monotone=all(lat[a] <= lat[b] * 1.02 for a, b in zip(WS_MB, WS_MB[1:])),
    )
