"""Tile-granular memory fidelity validation — the error-budget harness.

``Engine(mem_fidelity="tile")`` collapses each TMA tile load into one bulk
memory transaction (single completion event) instead of ``tile_lines``
per-line cache requests.  That trade is only usable if its error stays
*bounded and measured*, so this bench runs every registered kernel program
plus fa3 tiling/machine variants in both modes and asserts, per cell:

  * **byte-identical traffic** — ``dram_bytes``, ``tma_lines`` and L2
    *misses* must match the line-exact run exactly (the refcounted
    per-line residency model in ``TileMemory`` guarantees this even for
    overlapping tile boxes);
  * **bounded cycle error** — |tile - line| / line <= 5%;
  * **bounded L2 request error** — <= 2.5% relative OR <= 512 lines
    absolute.  Exactness is impossible here (line-exact merge windows
    depend on sub-cycle interleaving; see docs/fidelity.md), and the
    residual is a near-constant handful of mis-merged pair windows — a
    large *percentage* only on tiny launches with tiny request counts.

Cells run at full machine memory scale: that is tile fidelity's contract
(simfa only selects it for full-machine launches; scaled-memory subset
launches are the hierarchical tier's domain, see docs/fidelity.md).

The full run also measures the reference full-fidelity FA3 launch in both
modes (best-of-N wall) and gates tile speedup against a conservative
floor; the measured numbers back the committed error table in
docs/fidelity.md and the tile row family in BENCH_engine.json.

    PYTHONPATH=src:. python benchmarks/bench_fidelity.py           # full
    PYTHONPATH=src:. python benchmarks/bench_fidelity.py --smoke   # CI gate

``--smoke`` runs the kernel-program cells plus a medium-workload speedup
check with a lower floor (shared CI runners are noisy; the ~10x reference
number is only meaningful on a quiet host) and writes nothing.
"""
from __future__ import annotations

import math
import time

from repro.configs.llama3 import AttnWorkload
from repro.core.engine import Engine
from repro.core.kprog import registry
from repro.core.machine import H800, h800_variant
from repro.core.tracegen_fa3 import FA3Tiling, fa3_kernel_ctas

from benchmarks.common import Sink, maybe_profile

# error budget (acceptance bar; also asserted per-cell in
# tests/test_engine_equiv.py on the kernel grid)
CYCLE_ERR_MAX = 0.05        # |tile - line| / line cycles
L2_REQ_ERR_MAX = 0.025      # l2_req_bytes, relative ...
L2_REQ_ERR_MAX_LINES = 512  # ... or absolute (mis-merged pair windows)
EXACT_KEYS = ("dram_bytes", "tma_lines")    # plus L2 misses, byte-identical

# speedup floors on the tile-vs-line wall ratio.  The reference number is
# ~10x on the full launch on a quiet host (BENCH_engine.json); CI runners
# have multi-second contention phases, so the gates are deliberately loose
# enough to only catch "the fast path stopped being fast" regressions.
SPEEDUP_FLOOR_FULL = 5.0    # full reference launch, standalone runs
SPEEDUP_FLOOR_SMOKE = 2.0   # medium launch, shared CI hosts

# the reference full-fidelity FA3 launch (same as bench_engine "full")
FULL_W = dict(B=1, L=1024, S=2048, H_kv=2, G=2, D=128)
MEDIUM_W = dict(B=1, L=512, S=1024, H_kv=2, G=2, D=128)

# kernel-program cells: every registered kernel, small enough to run both
# modes in well under a second each (mirrors test_engine_equiv grid)
KERNEL_CELLS = {
    "fa3": (H800,
            AttnWorkload(name="p", B=1, L=256, S=512, H_kv=1, G=2, D=128),
            None),
    "fa3_cooperative": (h800_variant(num_sms=4),
                        AttnWorkload(name="c", B=1, L=256, S=512, H_kv=1,
                                     G=2, D=128), None),
    "fa2": (H800,
            AttnWorkload(name="f", B=1, L=192, S=384, H_kv=1, G=1, D=64),
            None),
    "splitkv_decode": (H800,
                       AttnWorkload(name="d", B=2, L=1, S=2048, H_kv=2,
                                    G=4, D=128), None),
}

# fa3 tiling / machine variants: exercise non-default tile shapes, stage
# counts, hash interleave, and a hard in-flight cap (full run only).
# mem_fidelity="tile" refuses lrc_enabled=False outright (build_memory
# raises): the no-LRC ablation is per-line request flooding by definition.
VARIANT_CELLS = {
    "fa3-t64x128s2": (H800,
                      dict(B=1, L=128, S=256, H_kv=1, G=1, D=64),
                      FA3Tiling(t_m=64, t_n=128, stages=2)),
    "fa3-t64x96s3": (h800_variant(xor_hash=False, remote_copy=False),
                     dict(B=1, L=192, S=384, H_kv=1, G=1, D=64),
                     FA3Tiling(t_m=64, t_n=96, stages=3)),
    "fa3-causal-cap8": (h800_variant(tma_max_inflight_lines=8),
                        dict(B=1, L=256, S=512, H_kv=1, G=1, D=128,
                             causal=True), None),
}


def _launch(cfg, ctas, tmaps, mem_fidelity):
    eng = Engine(cfg, mem_fidelity=mem_fidelity)
    for tm in tmaps.values():
        eng.define_tmap(tm)
    eng.launch(ctas)
    return eng.run()


def _kernel_cell(name):
    cfg, w, tiling = KERNEL_CELLS[name]
    ctas, tmaps = registry.get(name).build(cfg, w, tiling=tiling)
    return cfg, ctas, tmaps


def _variant_cell(name):
    cfg, kw, tiling = VARIANT_CELLS[name]
    kw = dict(kw)
    causal = kw.pop("causal", False)
    ctas, tmaps = fa3_kernel_ctas(cfg, tiling=tiling or FA3Tiling(),
                                  causal=causal, **kw)
    return cfg, ctas, tmaps


def check_cell(label, cfg, ctas, tmaps) -> dict:
    """Run one grid cell line-exact and tile, assert the error budget."""
    line = _launch(cfg, ctas, tmaps, "line")
    tile = _launch(cfg, ctas, tmaps, "tile")
    for key in EXACT_KEYS:
        assert line[key] == tile[key], (
            f"{label}: tile fidelity drifted on exact counter {key}: "
            f"line {line[key]} != tile {tile[key]}")
    assert line["l2"]["misses"] == tile["l2"]["misses"], (
        f"{label}: L2 miss count drifted: "
        f"{line['l2']['misses']} != {tile['l2']['misses']}")
    cyc_err = abs(tile["cycles"] / line["cycles"] - 1.0)
    l2_err = abs(tile["l2_req_bytes"] / line["l2_req_bytes"] - 1.0)
    l2_err_lines = abs(tile["l2"]["requests"] - line["l2"]["requests"])
    assert cyc_err <= CYCLE_ERR_MAX, (
        f"{label}: tile cycle error {cyc_err:.2%} exceeds "
        f"{CYCLE_ERR_MAX:.0%} bound ({tile['cycles']} vs {line['cycles']})")
    assert l2_err <= L2_REQ_ERR_MAX or l2_err_lines <= L2_REQ_ERR_MAX_LINES, (
        f"{label}: tile l2_req_bytes error {l2_err:.2%} "
        f"({l2_err_lines} lines) exceeds the {L2_REQ_ERR_MAX:.1%}-or-"
        f"{L2_REQ_ERR_MAX_LINES}-line bound")
    return {
        "cell": label,
        "cycles_line": line["cycles"],
        "cycles_tile": tile["cycles"],
        "cycle_err_pct": round(100.0 * cyc_err, 3),
        "l2_req_err_pct": round(100.0 * l2_err, 3),
        "l2_req_err_lines": l2_err_lines,
        "dram_bytes": line["dram_bytes"],
        "tma_lines": line["tma_lines"],
        "l2_misses": line["l2"]["misses"],
        "traffic_exact": True,
    }


def _wall_pair(w_kw: dict, repeats: int = 3):
    """Best-of-N wall seconds for the same launch in both fidelities."""
    tiling = FA3Tiling()
    total = (w_kw["B"] * w_kw["H_kv"] * w_kw["G"]
             * math.ceil(w_kw["L"] / tiling.t_m))
    ctas, tmaps = fa3_kernel_ctas(H800, tiling=tiling, max_ctas=total, **w_kw)
    walls = {}
    for mode in ("line", "tile"):
        best = math.inf
        for _ in range(repeats):
            eng = Engine(H800, mem_fidelity=mode)
            for tm in tmaps.values():
                eng.define_tmap(tm)
            t0 = time.perf_counter()
            eng.launch(ctas)
            eng.run()
            best = min(best, time.perf_counter() - t0)
        walls[mode] = best
    return walls["line"], walls["tile"]


def run(sink: Sink, smoke: bool = False, profile: bool = False):
    cells = [(n, _kernel_cell) for n in KERNEL_CELLS]
    if not smoke:
        cells += [(n, _variant_cell) for n in VARIANT_CELLS]
    max_cyc = max_l2 = 0.0
    with maybe_profile(profile):
        for label, builder in cells:
            row = check_cell(label, *builder(label))
            sink.row(**row)
            max_cyc = max(max_cyc, row["cycle_err_pct"])
            max_l2 = max(max_l2, row["l2_req_err_pct"])
        # wall speedup: full reference launch standalone, medium in smoke
        # (CI budget); floors are loose on purpose — see module docstring
        w_kw, floor = ((MEDIUM_W, SPEEDUP_FLOOR_SMOKE) if smoke
                       else (FULL_W, SPEEDUP_FLOOR_FULL))
        line_s, tile_s = _wall_pair(w_kw)
        speedup = line_s / tile_s
        assert speedup >= floor, (
            f"tile fidelity speedup collapsed: {speedup:.1f}x < {floor}x "
            f"floor (line {line_s:.3f}s, tile {tile_s:.3f}s)")
    sink.derive(
        cells=len(cells),
        max_cycle_err_pct=round(max_cyc, 3),
        max_l2_req_err_pct=round(max_l2, 3),
        wall_line_s=round(line_s, 4),
        wall_tile_s=round(tile_s, 4),
        speedup_tile_vs_line=round(speedup, 2),
        speedup_workload="medium" if smoke else "full",
    )
    return sink.rows


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="kernel cells + medium-launch speedup floor only; "
                         "write nothing (CI gate)")
    ap.add_argument("--profile", action="store_true")
    args = ap.parse_args()

    sink = Sink("fidelity")
    run(sink, smoke=args.smoke, profile=args.profile)
    if not args.smoke:
        sink.finish()
    print("fidelity " + ("smoke " if args.smoke else "") + "ok:",
          sink.derived)
    sys.exit(0)
