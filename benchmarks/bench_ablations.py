"""Paper Table 5 — architectural-factor ablations.

The paper removes one calibrated mechanism at a time and reports how far the
simulator drifts from H800 (MAPE 5.7% -> 16.8% / 64.3% / 511.4%). Without
hardware, the reproducible artifact is the *performance deterioration* each
mechanism prevents, measured as simulated-latency inflation over the full
Sim-FA configuration, with the paper's ordering:

    no-TMA-dedup  >>  naive slice hash  >>  no LRC.

Workload: GQA attention with H_kv=8, D=128 — the (B,S,H,D) layout's
2048-byte row stride is what defeats the naive low-bit hash (§5.4).
"""
from __future__ import annotations

from repro.configs.llama3 import AttnWorkload
from repro.core.machine import h800_variant
from repro.core.simfa import simulate_fa3

from benchmarks.common import Sink

W = AttnWorkload(name="ablation", B=1, L=256, S=512, H_kv=8, G=1, D=128)

VARIANTS = [
    ("sim_fa", {}),
    ("no_lrc", {"lrc_enabled": False}),
    ("naive_hash", {"xor_hash": False}),
    ("no_tma_dedup", {"tma_dedup": False}),
]

PAPER_MAPE = {"sim_fa": 0.057, "no_lrc": 0.168, "naive_hash": 0.643,
              "no_tma_dedup": 5.114}


def run(sink: Sink):
    base_cycles = None
    inflation = {}
    for name, kw in VARIANTS:
        cfg = h800_variant(**kw)
        r = simulate_fa3(W, cfg, fidelity="full")
        if base_cycles is None:
            base_cycles = r.cycles
        inflation[name] = r.cycles / base_cycles
        sink.row(variant=name, cycles=int(r.cycles),
                 latency_us=round(r.latency_us, 1),
                 l2_demand_gb=round(r.l2_bytes / 1e9, 4),
                 l2_delivered_gb=round(r.l2_delivered_bytes / 1e9, 4),
                 dram_gb=round(r.dram_bytes / 1e9, 4),
                 latency_inflation=round(inflation[name], 3),
                 paper_mape=PAPER_MAPE[name])
        assert not r.deadlocked, f"deadlock in {name}"

    sink.derive(
        ordering_matches_paper=(
            inflation["no_tma_dedup"] > inflation["naive_hash"]
            > inflation["no_lrc"] > 1.0),
        no_dedup_inflation=round(inflation["no_tma_dedup"], 2),
        naive_hash_inflation=round(inflation["naive_hash"], 2),
        no_lrc_inflation=round(inflation["no_lrc"], 2),
        note=("paper reports MAPE vs H800; we report latency inflation of "
              "the ablated simulator — the deterioration each mechanism "
              "prevents (same direction/ordering as Table 5)"),
    )
