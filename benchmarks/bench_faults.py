"""(ours) Variability sensitivity — perturbation-magnitude degradation curves.

The paper validates Sim-FA under ideal locked-frequency conditions; this
bench asks how fast the prediction degrades as measured Hopper variability
(``core.machine.H800_VARIABILITY``) is scaled up: one latency /
stall-attribution row per (scale, seed), collapsed to a mean/min/max
degradation curve, plus a straggler-deadline calibration from the modeled
step-time distribution (``serve.engine.StragglerPolicy.from_samples``).

``--smoke`` (CI fault-matrix step) shrinks the workload and additionally
runs the scheduler x plan matrix: the identity plan must be cycle-exact
across all three schedulers, and seeded perturbed runs must reproduce.
"""
from __future__ import annotations

from repro.configs.llama3 import AttnWorkload
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3
from repro.faults import FaultPlan, measured_variability
from repro.faults.sensitivity import (
    DEFAULT_SCALES,
    degradation_curve,
    sensitivity_sweep,
    step_time_samples,
)

from benchmarks.common import Sink

W = AttnWorkload(name="fa3_var", B=1, L=256, S=512, H_kv=2, G=2, D=128)
W_SMOKE = AttnWorkload(name="fa3_var_smoke", B=1, L=128, S=256, H_kv=1,
                       G=1, D=128)
SCHEDULERS = ("event", "waiter", "broadcast")


def _scheduler_matrix(sink: Sink, w) -> None:
    """Scheduler x plan matrix (the CI gate): identity bit-exact across
    schedulers, seeded perturbation reproducible under each."""
    base = None
    for sched in SCHEDULERS:
        opts = {"scheduler": sched}
        r_id = simulate_fa3(w, H800, faults=FaultPlan.identity(),
                            engine_opts=opts)
        r_p1 = simulate_fa3(w, H800, faults=measured_variability(seed=3),
                            engine_opts=opts)
        r_p2 = simulate_fa3(w, H800, faults=measured_variability(seed=3),
                            engine_opts=opts)
        if base is None:
            base = r_id.cycles
        assert r_id.cycles == base, \
            f"identity plan not bit-exact under {sched}"
        assert r_p1.cycles == r_p2.cycles, \
            f"seeded run not reproducible under {sched}"
        sink.row(matrix=sched, identity_cycles=int(r_id.cycles),
                 perturbed_cycles=int(r_p1.cycles))


def run(sink: Sink, smoke: bool = False):
    w = W_SMOKE if smoke else W
    scales = (0.0, 1.0) if smoke else DEFAULT_SCALES
    seeds = (0,) if smoke else (0, 1, 2)
    rows = sensitivity_sweep(w, H800, fidelity="auto", scales=scales,
                             seeds=seeds, record_stalls=not smoke)
    for r in rows:
        sink.row(**{k: v for k, v in r.items() if v is not None})

    curve = degradation_curve(rows)
    assert curve[0]["mean"] == 1.0, \
        "scale-0 must be bit-exact with the unperturbed model"
    for p in curve:
        sink.derive(**{f"degradation_x{p['scale']:g}": round(p["mean"], 4)})
    sink.derive(max_degradation=round(curve[-1]["max"], 4))

    # straggler-deadline calibration from the modeled distribution
    samples = step_time_samples(w, H800, scale=1.0, n=4 if smoke else 12)
    from repro.serve.engine import StragglerPolicy
    pol = StragglerPolicy.from_samples(samples)
    sink.derive(straggler_expected_step_us=round(pol.expected_step_s * 1e6, 1),
                straggler_factor=round(pol.factor, 3))

    if smoke:
        _scheduler_matrix(sink, w)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, 2 scales, plus the scheduler x "
                         "plan bit-exactness matrix (the CI gate)")
    args = ap.parse_args()
    sink = Sink("faults")
    run(sink, smoke=args.smoke)
    out = sink.finish()
    print(f"faults bench ok ({out['wall_s']}s): {len(out['rows'])} rows -> "
          f"results/bench/faults.json; derived={out['derived']}")
