"""Roofline terms per (arch x shape) from the multi-pod dry-run artifacts.

Reads ``results/dryrun.json`` (produced by ``repro.launch.dryrun``) and
reports the three-term roofline per cell — the §Roofline deliverable in
benchmark form. Skips gracefully if the dry-run has not been executed.
"""
from __future__ import annotations

from pathlib import Path

from repro.launch import roofline

from benchmarks.common import Sink

DRYRUN = Path("results/dryrun.json")


def run(sink: Sink):
    if not DRYRUN.exists():
        sink.derive(skipped="results/dryrun.json missing — run "
                            "`python -m repro.launch.dryrun` first")
        return
    rows = roofline.analyze_all(DRYRUN)
    bounds = {"compute": 0, "memory": 0, "collective": 0}
    for r in rows.values():
        if r["mesh"] != "single_pod_16x16":
            continue
        bounds[r["bottleneck"]] += 1
        sink.row(arch=r["arch"], shape=r["shape"],
                 compute_s=round(r["t_compute_s"], 4),
                 memory_s=round(r["t_memory_s"], 4),
                 collective_s=round(r["t_collective_s"], 4),
                 bound=r["bottleneck"],
                 useful_ratio=round(r["useful_flops_ratio"], 3),
                 roofline_frac=round(r["roofline_fraction"], 3))
    singles = [r for r in rows.values() if r["mesh"] == "single_pod_16x16"]
    sink.derive(cells=len(singles),
                bound_histogram=bounds,
                mean_roofline_frac=round(
                    sum(r["roofline_fraction"] for r in singles)
                    / max(len(singles), 1), 3))
