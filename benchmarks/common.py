"""Shared benchmark plumbing: result sink, CSV rows, MAPE helpers.

Every ``bench_*`` module exposes ``run(sink) -> None`` and registers rows via
``sink.row(...)``; ``benchmarks/run.py`` orchestrates and writes
``results/bench/<name>.json`` plus a flat CSV stream on stdout.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Any, Dict, List

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results" / "bench"


@contextlib.contextmanager
def maybe_profile(enabled: bool, top: int = 20, sort: str = "cumulative"):
    """``--profile`` mode: cProfile the enclosed block and dump the top-N
    functions (by cumulative time) to stdout.  No-op when disabled, so
    benches can wrap their hot section unconditionally."""
    if not enabled:
        yield
        return
    import cProfile
    import io
    import pstats

    prof = cProfile.Profile()
    prof.enable()
    try:
        yield
    finally:
        prof.disable()
        s = io.StringIO()
        pstats.Stats(prof, stream=s).sort_stats(sort).print_stats(top)
        print(s.getvalue(), flush=True)


class Sink:
    """Collects benchmark rows + derived summary metrics."""

    def __init__(self, name: str, quiet: bool = False):
        self.name = name
        self.rows: List[Dict[str, Any]] = []
        self.derived: Dict[str, Any] = {}
        self.quiet = quiet
        self.t0 = time.time()

    def row(self, **kw):
        self.rows.append(kw)
        if not self.quiet:
            print(f"  {self.name}," + ",".join(f"{k}={_fmt(v)}" for k, v in kw.items()),
                  flush=True)

    def derive(self, **kw):
        self.derived.update(kw)

    def finish(self) -> Dict[str, Any]:
        out = {"bench": self.name, "rows": self.rows, "derived": self.derived,
               "wall_s": round(time.time() - self.t0, 2)}
        from repro.utils.ioutil import atomic_write_json
        atomic_write_json(str(RESULTS_DIR / f"{self.name}.json"), out)
        return out


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, dict):
        # provenance manifests ride along on bench rows; keep the CSV
        # stream readable with just the identity bits
        if "git_sha" in v and "host_id" in v:
            return f"{v['git_sha']}@{v['host_id']}"
        return json.dumps(v, sort_keys=True)
    return v


def mape(pairs) -> float:
    """Mean absolute percentage error over (predicted, reference) pairs."""
    errs = [abs(p - r) / abs(r) for p, r in pairs if r]
    return sum(errs) / max(len(errs), 1)


def max_ape(pairs) -> float:
    errs = [abs(p - r) / abs(r) for p, r in pairs if r]
    return max(errs) if errs else 0.0
