"""What-if replay validation + speed benchmark (acceptance criteria).

Checks, on a full-fidelity FA3 launch:
  1. DAG replay with every knob at x1.0 matches the cycle engine's makespan
     to within 1%;
  2. a 3-point TMA-bandwidth what-if sweep via replay completes >=10x faster
     than re-simulating each point;
  3. replay predictions for integer-compatible knob points track real
     re-simulation (reported as relative error per point).
"""
from __future__ import annotations

import time

from repro.analysis import dag as dag_mod
from repro.analysis import whatif
from repro.configs.llama3 import AttnWorkload
from repro.core.machine import H800
from repro.core.simfa import simulate_fa3

from benchmarks.common import Sink

WORKLOAD = AttnWorkload(name="fa3-bench", B=1, L=1024, S=2048, H_kv=2, G=2,
                        D=128)
TMA_POINTS = (0.5, 1.0, 2.0)


def run(sink: Sink):
    w, cfg = WORKLOAD, H800
    t0 = time.perf_counter()
    base = simulate_fa3(w, cfg, fidelity="full", record_events=True)
    sim_s = time.perf_counter() - t0
    dag = dag_mod.build(base.trace.events, base.trace.dispatch_parent)

    # (1) x1.0 identity
    r1 = whatif.replay(dag)
    id_err = abs(r1.makespan - base.cycles) / base.cycles
    sink.row(check="identity", pred=r1.makespan, sim=base.cycles,
             rel_err=id_err, ok=id_err <= 0.01)

    # (2) 3-point TMA sweep: replay vs re-simulate
    t0 = time.perf_counter()
    preds = {k: whatif.replay(dag, whatif.Knobs(tma_bw=k)).makespan
             for k in TMA_POINTS}
    replay_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    resims = {}
    for k in TMA_POINTS:
        if k == 1.0:
            resims[k] = base.cycles
            continue
        r = simulate_fa3(w, whatif.machine_for(cfg, whatif.Knobs(tma_bw=k)),
                         fidelity="full")
        resims[k] = r.cycles
    resim_s = time.perf_counter() - t0

    speedup = resim_s / max(replay_s, 1e-9)
    sink.row(check="sweep_speed", replay_s=replay_s, resim_s=resim_s,
             speedup=speedup, ok=speedup >= 10.0)

    # (3) accuracy per point
    for k in TMA_POINTS:
        err = abs(preds[k] - resims[k]) / max(resims[k], 1e-9)
        sink.row(check="tma_point", tma_bw=k, pred=preds[k], resim=resims[k],
                 rel_err=err)

    sink.derived.update({
        "identity_rel_err": id_err,
        "sweep_speedup_vs_resim": speedup,
        "events": len(base.trace.events),
        "sim_s": sim_s,
    })


if __name__ == "__main__":
    import sys

    s = Sink("whatif")
    run(s)
    print(s.derived)
    # enforce the acceptance criteria when run standalone (CI step)
    failed = [r for r in s.rows if r.get("ok") is False]
    if failed:
        print(f"ACCEPTANCE FAILED: {failed}")
        sys.exit(1)
