"""Shared harness for the MSHR sweep (kept separate so bench_mshr and tests
import it without circularity)."""
from __future__ import annotations

from benchmarks.bench_tma_bandwidth import bandwidth_case


def measure_bw_2d(cfg, n_sms: int = 132, tiles_per_sm: int = 24):
    e = 2
    return bandwidth_case(
        cfg, name="2d_64x64", box=(1, 64, 64), dims=(1, 1 << 20, 64),
        strides=(1 << 40, 64 * e, e), bulk=False, n_sms=n_sms,
        tiles_per_sm=tiles_per_sm)
